"""TransientGym — a trace-driven controller that trains under the trace.

Two phases, deliberately decoupled because training losses never feed
back into cloud scheduling:

**Phase 1 — plan** (no JAX): a scalar wall-clock fleet model replays the
trace from t=0 ("zero" bootstrap — the realized timeline). At each
decision epoch the policy's ``act(observation)`` replans the fleet
(provision/release/refill); between epochs an event loop integrates the
PS-capped step rate through revocations, join activations
(``JOIN_OVERHEAD_S``), and completion — the same event semantics as
``core/mc.py``, implemented independently, which is exactly what makes
the differential validation in ``gym/validate.py`` meaningful. The
output is a ``GymLedger``: per-epoch records (spot quote via
``pricing.price_at``, billed cost, virtual steps, fleet size) plus the
realized membership timeline as ``SlotEvent``s.

**Phase 2 — execute**: the timeline is rescaled from the paper's virtual
workload (64K steps) to a reduced training run and fed as
warn/revoke/join events into

- ``ElasticRuntime`` (masked mode): real JAX training of a reduced
  config, eval accuracy measured on held-out data (the planted
  ``Cifar10Like`` task for the resnet family, next-token accuracy for
  LMs), revocation warnings triggering fast checkpoint saves;
- ``AsyncPSSimulator``: the same membership timeline in update space,
  yielding the staleness histogram of the async-PS reproduction.

Step-space mapping: an event at virtual step ``v`` lands on training
step ``round(v * train_steps / total_steps)``; wall-clock order is
preserved within a training step, so a refill that activates while the
fleet is dead (virtual steps frozen) is applied *after* the revocations
that emptied it and the cluster never goes empty mid-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core import mc, pricing
from repro.core.policy import (Policy, PolicyDecision, StaticPolicy,
                               make_observation)
from repro.core.simulator import (DEFAULT_TOTAL_STEPS, JOIN_OVERHEAD_S,
                                  Summary, ps_capped_rate)
from repro.hetero.profiles import composition as kind_composition
from repro.hetero.rates import _check_mode, aggregate_rate
from repro.traces.replay import ReplayContext

# Event-type tags on the wall-clock membership timeline.
EV_JOIN = "join"          # slot activated (initial fleet or later refill)
EV_REVOKE = "revoke"      # provider revoked the server (lifetime expired)
EV_RELEASE = "release"    # policy released the server (switch / shrink)


@dataclasses.dataclass(frozen=True)
class SlotEvent:
    """One membership change on the realized timeline."""
    t_s: float            # wall-clock seconds since trace start
    vstep: float          # cumulative virtual steps at the event
    slot: int             # cluster slot index (stable; reused after revoke)
    kind: str             # EV_JOIN | EV_REVOKE | EV_RELEASE
    server_kind: str      # "K80" | "P100" | "V100"
    region: str = "us-east1"


@dataclasses.dataclass(frozen=True)
class EpochRecord:
    """Per-decision-epoch ledger line (wall-clock model view)."""
    epoch: int
    t_s: float
    vsteps: float         # virtual steps completed at epoch start
    n_active: int         # active workers after reconciling to the decision
    decision: str         # PolicyDecision.label
    spot_price_hr: float  # pricing.price_at for the decision's kind
    cost_usd: float       # cumulative billed cost at epoch start
    revocations: int      # cumulative lifetime revocations
    n_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)
    # ^ active-fleet composition (mixed fleets: the hetero layer's view)


@dataclasses.dataclass
class GymLedger:
    """Everything one gym episode produced, summarizable as the engine's
    ``Summary`` schema (``core/mc.py`` codes in ``status``)."""
    trace: str
    policy: str
    total_steps: int              # virtual workload (engine scale)
    status: int                   # mc.COMPLETED / mc.ALL_REVOKED / ...
    time_h: float
    cost_usd: float
    vsteps_done: float
    avg_active_workers: float
    revocations: int
    max_slots: int
    epochs: List[EpochRecord]
    schedule: List[SlotEvent]
    # per-kind billed cost breakout ("PS" included) — heterogeneous fleets
    # are priced per kind, so the ledger shows where the dollars went
    cost_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    batching: str = "dynamic"     # work-division mode the plan priced;
                                  # phase-2 execution must match it
    # phase-2 results (filled by the executors; NaN/0 when plan-only)
    executed_steps: int = 0
    accuracy: float = float("nan")        # real eval accuracy in [0, 1]
    final_loss: float = float("nan")
    fast_saves: int = 0
    staleness_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    mean_staleness: float = 0.0

    @property
    def completed(self) -> bool:
        return self.status == mc.COMPLETED

    @property
    def failure(self) -> Optional[str]:
        return mc.FAILURE_NAMES.get(self.status, "unknown")

    def summary(self) -> Summary:
        return summarize_ledgers([self])

    def to_dict(self) -> Dict:
        """JSON view for the CLI / benchmark artifacts."""
        return {
            "trace": self.trace, "policy": self.policy,
            "total_steps": self.total_steps,
            "completed": self.completed, "failure": self.failure,
            "time_h": self.time_h, "cost_usd": self.cost_usd,
            "vsteps_done": self.vsteps_done,
            "avg_active_workers": self.avg_active_workers,
            "revocations": self.revocations, "max_slots": self.max_slots,
            "cost_by_kind": dict(self.cost_by_kind),
            "batching": self.batching,
            "executed_steps": self.executed_steps,
            "accuracy": None if math.isnan(self.accuracy) else self.accuracy,
            "final_loss": (None if math.isnan(self.final_loss)
                           else self.final_loss),
            "fast_saves": self.fast_saves,
            "mean_staleness": self.mean_staleness,
            "staleness_hist": {str(k): v
                               for k, v in self.staleness_hist.items()},
            "epochs": [dataclasses.asdict(e) for e in self.epochs],
            "schedule": [dataclasses.asdict(e) for e in self.schedule],
        }


def summarize_ledgers(ledgers: List[GymLedger]) -> Summary:
    """Aggregate gym episodes into the engine's ``Summary`` schema via the
    shared ``mc.summarize_arrays`` seam — field-for-field comparable with
    ``simulate_many`` output. ``acc`` aggregates the *real* eval accuracy
    (fraction in [0, 1]) over the completed ledgers that measured one;
    plan-only ledgers carry a NaN placeholder, which the aggregation
    skips (all-plan-only input yields the finite degenerate (0, 0))."""
    status = np.array([l.status for l in ledgers], dtype=np.int64)
    acc = np.array([l.accuracy for l in ledgers])
    acc = np.where(status == mc.COMPLETED, acc, np.nan)
    return mc.summarize_arrays(
        status,
        np.array([l.time_h for l in ledgers]),
        np.array([l.cost_usd for l in ledgers]),
        acc,
        np.array([l.revocations for l in ledgers], dtype=np.int64))


# ---------------------------------------------------------------------------
# Phase 1: the wall-clock fleet model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """Internal per-server state of the fleet model."""
    kind: str
    cid: int                      # cluster slot index
    region: str = "us-east1"
    t_pending: float = np.inf     # activation due time; inf = not pending
    t_start: float = np.nan       # activation time; NaN = never activated
    t_revoke: float = np.inf      # drawn lifetime expiry (absolute)
    t_release: float = np.inf     # policy released it (absolute)
    active: bool = False

    @property
    def live(self) -> bool:
        return self.active or np.isfinite(self.t_pending)


class TransientGym:
    """One policy episode over one trace: plan, then optionally train.

    ``refill=False`` reproduces the engine's static-fleet semantics
    (provision once at t=0, revoked slots stay dead — the differential-
    validation mode); ``refill=True`` is the online-policy flow of
    ``evaluate_policy`` (reconcile the fleet to the decision every
    epoch). Parameter servers are on-demand, like the policy evaluator.
    """

    def __init__(self, trace, policy: Optional[Policy] = None, *,
                 total_steps: int = DEFAULT_TOTAL_STEPS,
                 epoch_s: float = 1800.0, max_h: float = 24.0,
                 refill: bool = False, seed: int = 0,
                 batching: str = "dynamic",
                 recorder: Optional[obs.Recorder] = None):
        _check_mode(batching)
        if isinstance(trace, ReplayContext):
            self.ctx = trace
        else:
            # "zero" bootstrap: the gym replays the one realized timeline
            self.ctx = ReplayContext(trace, bootstrap="zero")
        self.policy = policy if policy is not None \
            else StaticPolicy(PolicyDecision("K80", 4))
        self.total_steps = int(total_steps)
        self.epoch_s = float(epoch_s)
        self.max_h = float(max_h)
        self.refill = bool(refill)
        self.seed = int(seed)
        self.batching = batching      # mixed-fleet work division model
        # observability: events/metrics route through here; the NULL
        # recorder keeps every emission a constant-time no-op
        self.rec = recorder if recorder is not None else obs.NULL

    # -- wall-clock model -------------------------------------------------

    def plan(self) -> GymLedger:
        rng = np.random.default_rng(self.seed)
        self.policy.reset(rng)
        bound = self.ctx.bind(1, rng, bootstrap="zero")
        zero = np.zeros(1, dtype=np.int64)

        slots: List[_Slot] = []
        free_cids: List[int] = []
        next_cid = 0
        events: List[SlotEvent] = []
        epochs: List[EpochRecord] = []

        t = 0.0
        vsteps = 0.0
        worker_int = 0.0              # ∫ active_workers dt
        ps_int = 0.0                  # ∫ n_ps dt (on-demand billing)
        revocations = 0
        status = mc.RUNNING
        total = float(self.total_steps)
        max_s = self.max_h * 3600.0

        def alloc_cid() -> int:
            nonlocal next_cid
            if free_cids:
                return free_cids.pop(0)
            next_cid += 1
            return next_cid - 1

        def draw_lifetime(kind: str, at: float) -> float:
            return float(bound.lifetimes(kind, zero, at, rng)[0])

        def cost_by_kind_until(tq: float) -> Dict[str, float]:
            by_kind: Dict[str, float] = {}
            for s in slots:
                if not np.isfinite(s.t_start):
                    continue
                end = min(s.t_revoke, s.t_release, tq)
                secs = max(0.0, end - s.t_start)
                if self.ctx.has_prices(s.kind):
                    c = float(bound.cost_usd(s.kind,
                                             np.array([s.t_start]),
                                             np.array([s.t_start + secs]))[0])
                else:
                    c = secs * pricing.SERVER_TYPES[s.kind].transient_hr \
                        / 3600.0
                by_kind[s.kind] = by_kind.get(s.kind, 0.0) + c
            by_kind["PS"] = ps_int * pricing.SERVER_TYPES["PS"].ondemand_hr \
                / 3600.0
            return by_kind

        def cost_until(tq: float) -> float:
            return sum(cost_by_kind_until(tq).values())

        rec = self.rec
        k = 0
        dec: Optional[PolicyDecision] = None
        while status == mc.RUNNING:
            t_epoch = k * self.epoch_s
            if t_epoch >= max_s:
                break

            # --- observe + act (the online policy interface) -------------
            fleet_now = kind_composition(s.kind for s in slots if s.active)
            observation = make_observation(self.ctx, t_s=t_epoch,
                                           steps_done=vsteps,
                                           total_steps=self.total_steps,
                                           fleet_by_kind=fleet_now)
            with rec.span(obs.EV_REPLAN, cat=obs.CAT_POLICY,
                          sim_t=t_epoch, epoch=k) as replan_args:
                dec = self.policy.act(observation, self.ctx)
                if rec.enabled:
                    replan_args["decision"] = dec.label
                    replan_args["vsteps"] = vsteps
                    replan_args["fleet_by_kind"] = dict(fleet_now)
                    scores = getattr(self.policy, "last_scores", None)
                    if scores:                # considered-candidate metadata
                        replan_args["candidates"] = dict(scores)

            # --- reconcile the fleet to the decision (per target kind) ----
            if k == 0 or self.refill:
                target = dec.composition()
                # release live slots of untargeted types
                for s in slots:
                    if s.live and s.kind not in target:
                        if s.active:
                            s.t_release = t_epoch
                            s.active = False
                            events.append(SlotEvent(t_epoch, vsteps, s.cid,
                                                    EV_RELEASE, s.kind,
                                                    s.region))
                            rec.instant(obs.EV_SLOT_RELEASE, cat=obs.CAT_GYM,
                                        track=f"slot{s.cid}", sim_t=t_epoch,
                                        kind=s.kind, region=s.region)
                        s.t_pending = np.inf
                        free_cids.append(s.cid)
                for tkind, t_n in target.items():
                    # shrink surplus of this type, last-provisioned first
                    live = [s for s in slots if s.live and s.kind == tkind]
                    for s in reversed(live[t_n:]):
                        if s.active:
                            s.t_release = t_epoch
                            s.active = False
                            events.append(SlotEvent(t_epoch, vsteps, s.cid,
                                                    EV_RELEASE, s.kind,
                                                    s.region))
                            rec.instant(obs.EV_SLOT_RELEASE, cat=obs.CAT_GYM,
                                        track=f"slot{s.cid}", sim_t=t_epoch,
                                        kind=s.kind, region=s.region)
                        s.t_pending = np.inf
                        free_cids.append(s.cid)
                    # grow: initial provisioning (k=0) is free, like the
                    # engine's slot 0; later joins pay sparse-mapping cost
                    need = t_n - min(len(live), t_n)
                    overhead = 0.0 if k == 0 else JOIN_OVERHEAD_S
                    for _ in range(need):
                        slots.append(_Slot(kind=tkind, cid=alloc_cid(),
                                           t_pending=t_epoch + overhead))

            n_act = sum(1 for s in slots if s.active)
            n_by_kind = kind_composition(s.kind for s in slots if s.active)
            by_kind_epoch = cost_by_kind_until(max(t, t_epoch))
            epochs.append(EpochRecord(
                epoch=k, t_s=t_epoch, vsteps=vsteps, n_active=n_act,
                decision=dec.label,
                spot_price_hr=float(pricing.price_at(dec.kind, t_epoch,
                                                     trace=self.ctx)),
                cost_usd=sum(by_kind_epoch.values()),
                revocations=revocations,
                n_by_kind=n_by_kind))
            if rec.enabled:
                # per-epoch ledger fields as labeled series (previously
                # computed here and dropped): billed dollars and active
                # workers per server kind
                for kd, c in by_kind_epoch.items():
                    rec.metrics.gauge("cost_usd", kind=kd).set(c)
                for kd, n in n_by_kind.items():
                    rec.metrics.gauge("workers", kind=kd).set(n)
                rec.metrics.gauge("vsteps").set(vsteps)

            # --- advance the segment [t_epoch, t_epoch + epoch_s) ---------
            t = max(t, t_epoch)
            t_seg_end = min(t_epoch + self.epoch_s, max_s)
            for _ in range(mc._MAX_EVENTS):
                # hetero layer: uniform batching on a mixed fleet runs at
                # the slowest member's pace; dynamic recovers sum-of-rates
                rate = ps_capped_rate(
                    aggregate_rate(
                        np.array([pricing.SERVER_TYPES[s.kind].steps_per_sec
                                  for s in slots if s.active]),
                        self.batching), dec.n_ps)
                n_active = sum(1 for s in slots if s.active)
                t_rev = min((s.t_revoke for s in slots if s.active),
                            default=np.inf)
                t_act = min((s.t_pending for s in slots
                             if np.isfinite(s.t_pending)), default=np.inf)
                t_done = t + (total - vsteps) / rate if rate > 0 else np.inf

                if rate <= 0 and not np.isfinite(t_act) and not self.refill:
                    status = mc.ALL_REVOKED        # engine's dead criterion
                    break
                # tie-break order mirrors the engine: revoke < activate <
                # done (< segment boundary)
                t_next, what = min((t_rev, "revoke"), (t_act, "activate"),
                                   (t_done, "done"), (t_seg_end, "seg_end"),
                                   key=lambda e: e[0])
                dt = max(0.0, t_next - t)
                vsteps += rate * dt
                worker_int += n_active * dt
                ps_int += dec.n_ps * dt
                if rec.enabled and dt > 0 and rate > 0:
                    # one constant-rate segment of virtual progress
                    rec.sim_span(obs.EV_STEP, cat=obs.CAT_GYM, t0=t,
                                 t1=t_next, rate=rate, vsteps=rate * dt,
                                 n_active=n_active)
                t = t_next

                if what == "done":
                    vsteps = total
                    status = mc.COMPLETED
                    break
                if what == "seg_end":
                    break
                if what == "revoke":
                    s = min((s for s in slots if s.active),
                            key=lambda s: s.t_revoke)
                    s.active = False
                    revocations += 1
                    events.append(SlotEvent(t, vsteps, s.cid, EV_REVOKE,
                                            s.kind, s.region))
                    free_cids.append(s.cid)
                    if rec.enabled:
                        rec.instant(obs.EV_REVOKE_FIRE, cat=obs.CAT_GYM,
                                    track=f"slot{s.cid}", sim_t=t,
                                    kind=s.kind, region=s.region,
                                    vstep=vsteps)
                        rec.metrics.counter("revocations_total", kind=s.kind,
                                            region=s.region).inc()
                elif what == "activate":
                    s = min((s for s in slots if np.isfinite(s.t_pending)),
                            key=lambda s: s.t_pending)
                    s.t_pending = np.inf
                    s.t_start = t
                    s.active = True
                    s.t_revoke = t + draw_lifetime(s.kind, t)
                    events.append(SlotEvent(t, vsteps, s.cid, EV_JOIN,
                                            s.kind, s.region))
                    if rec.enabled:
                        rec.instant(obs.EV_SLOT_JOIN, cat=obs.CAT_GYM,
                                    track=f"slot{s.cid}", sim_t=t,
                                    kind=s.kind, region=s.region,
                                    vstep=vsteps)
            k += 1

        if status == mc.RUNNING:                   # hit the max_h wall
            status = mc.NO_PROGRESS
        t_end = min(t, max_s)
        avg_w = worker_int / t_end if t_end > 0 else 0.0
        by_kind = cost_by_kind_until(t_end)
        if rec.enabled:
            # final ledger totals as metrics: the gauges are set from the
            # very same by_kind dict / vsteps float the ledger is built
            # from, so registry.total("cost_usd") == ledger.cost_usd and
            # gauge("vsteps") == ledger.vsteps_done bit-for-bit
            for kd, c in by_kind.items():
                rec.metrics.gauge("cost_usd", kind=kd).set(c)
            rec.metrics.gauge("vsteps").set(vsteps)
            rec.metrics.counter("steps_total", kind="virtual").inc(vsteps)
            rec.metrics.gauge("time_h").set(t_end / 3600.0)
            rec.sim_span(obs.EV_EPISODE, cat=obs.CAT_GYM, t0=0.0, t1=t_end,
                         trace=self.ctx.trace.name, policy=self.policy.name,
                         status=int(status),
                         completed=status == mc.COMPLETED)
        return GymLedger(
            trace=self.ctx.trace.name, policy=self.policy.name,
            total_steps=self.total_steps, status=int(status),
            time_h=t_end / 3600.0, cost_usd=sum(by_kind.values()),
            vsteps_done=vsteps, avg_active_workers=avg_w,
            revocations=revocations, max_slots=max(next_cid, 1),
            epochs=epochs, schedule=events, cost_by_kind=by_kind,
            batching=self.batching)

    # -- full episode: plan + train + async staleness ----------------------

    def run(self, *, arch: str = "resnet32-cifar10", train_steps: int = 96,
            per_slot: int = 4, seq_len: int = 32,
            async_updates: int = 0, ckpt=None) -> GymLedger:
        """Plan, then execute the realized timeline as real training.

        ``async_updates > 0`` additionally replays the timeline through
        ``AsyncPSSimulator`` to fill the staleness histogram.
        """
        ledger = self.plan()
        execute_masked(ledger, arch=arch, train_steps=train_steps,
                       per_slot=per_slot, seq_len=seq_len, seed=self.seed,
                       ckpt=ckpt, recorder=self.rec)
        if async_updates > 0:
            execute_async_ps(ledger, updates=async_updates, seed=self.seed,
                             recorder=self.rec)
        return ledger


# ---------------------------------------------------------------------------
# Timeline -> training-step schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainingSchedule:
    """The wall-clock timeline rescaled to a reduced training run."""
    executed_steps: int                       # training steps to actually run
    initial: Tuple[Tuple[int, str], ...]      # (slot, server_kind) at step 0
    events: Tuple                             # elastic.RevocationEvent, ...


def training_schedule(ledger: GymLedger, train_steps: int
                      ) -> TrainingSchedule:
    """Map virtual-step events onto ``train_steps`` real training steps.

    Events keep their wall-clock order within a training step (see the
    module docstring for why that keeps the cluster non-empty); lifetime
    revocations get a GCE-style warning event one step earlier so the
    elastic runtime exercises the fast-save path.
    """
    from repro.core.elastic import RevocationEvent   # late: imports jax
    scale = train_steps / float(ledger.total_steps)
    if ledger.completed:
        executed = train_steps
    else:
        executed = min(train_steps, int(ledger.vsteps_done * scale))
    initial: List[Tuple[int, str]] = []
    events: List = []
    warned = set()
    for ev in ledger.schedule:
        step = int(round(ev.vstep * scale))
        if ev.kind == EV_JOIN and ev.t_s == 0.0:
            initial.append((ev.slot, ev.server_kind))
            continue
        if step >= executed:
            continue                     # after the run's end: never executed
        if ev.kind == EV_JOIN:
            events.append(RevocationEvent(step=step, slot=ev.slot,
                                          kind="join",
                                          server_kind=ev.server_kind,
                                          region=ev.region))
        else:
            if ev.kind == EV_REVOKE:     # 30 s warning -> fast checkpoint
                wstep = max(step - 1, 0)
                if (ev.slot, step) not in warned:
                    events.append(RevocationEvent(step=wstep, slot=ev.slot,
                                                  kind="warn",
                                                  server_kind=ev.server_kind,
                                                  region=ev.region))
                    warned.add((ev.slot, step))
            events.append(RevocationEvent(step=step, slot=ev.slot,
                                          kind="revoke",
                                          server_kind=ev.server_kind,
                                          region=ev.region))
    return TrainingSchedule(executed_steps=executed, initial=tuple(initial),
                            events=tuple(events))


# ---------------------------------------------------------------------------
# Phase 2a: masked elastic training of a reduced config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _PlantedSharded:
    """``ShardedDataset``-compatible view of ``Cifar10Like`` — planted
    signal so eval accuracy actually moves with executed steps."""
    task: object
    global_batch: int

    def shard_batch(self, step: int, shard: int, num_shards: int):
        if self.global_batch % num_shards:
            raise ValueError(f"global batch {self.global_batch} not "
                             f"divisible by {num_shards} shards")
        return self.task.batch(step, self.global_batch // num_shards,
                               shard=shard, num_shards=num_shards)

    def global_batch_at(self, step: int):
        return self.task.batch(step, self.global_batch)


def _build_training(arch: str, ledger: GymLedger, train_steps: int,
                    per_slot: int, seq_len: int, seed: int,
                    base_workers: int = 1):
    """Reduced model + dataset + train config for one gym execution."""
    from repro.config import (OptimizerConfig, ScheduleConfig, TrainConfig,
                              get_config)
    from repro.data.pipeline import Cifar10Like, ShardedDataset
    from repro.models.builder import build_model

    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    global_batch = per_slot * ledger.max_slots
    if cfg.family == "resnet":
        # color_signal: the planted class signal must survive the resnet's
        # global average pool for eval accuracy to track training progress
        task = Cifar10Like(num_classes=cfg.num_classes,
                           image_size=cfg.image_size, seed=seed,
                           color_signal=1.5)
        dataset = _PlantedSharded(task, global_batch)
        opt = OptimizerConfig(name="momentum", lr=0.05, adaptive_lr=True,
                              base_workers=base_workers, grad_clip=1.0)
    else:
        dataset = ShardedDataset(cfg, global_batch=global_batch,
                                 seq_len=seq_len, seed=seed)
        opt = OptimizerConfig(name="adamw", lr=3e-4, adaptive_lr=True,
                              base_workers=base_workers)
    tcfg = TrainConfig(optimizer=opt,
                       schedule=ScheduleConfig(kind="constant",
                                               warmup_steps=1,
                                               total_steps=train_steps),
                       checkpoint_every=0, seed=seed)
    return cfg, model, dataset, tcfg


def _eval_batch(cfg, dataset):
    if cfg.family == "resnet":
        return dataset.task.eval_batch(512)
    return dataset.global_batch_at(10_000_019)    # held-out step namespace


def execute_masked(ledger: GymLedger, *, arch: str = "resnet32-cifar10",
                   train_steps: int = 96, per_slot: int = 4,
                   seq_len: int = 32, seed: int = 0, ckpt=None,
                   recorder: Optional[obs.Recorder] = None) -> GymLedger:
    """Train the realized timeline with the masked elastic runtime.

    Fills ``executed_steps``, ``accuracy`` (held-out eval), ``final_loss``
    and ``fast_saves`` on the ledger, in place. ``recorder`` observes the
    real training steps (step spans on the step-index sim clock, the
    warn/revoke/join membership events) alongside the plan's sim events.
    """
    import jax
    from repro.core.cluster import SparseCluster
    from repro.core.elastic import ElasticRuntime
    from repro.train.step import init_state
    from repro.train.trainer import evaluate_accuracy

    sched = training_schedule(ledger, train_steps)
    cfg, model, dataset, tcfg = _build_training(
        arch, ledger, train_steps, per_slot, seq_len, seed,
        base_workers=max(len(sched.initial), 1))
    cluster = SparseCluster(max_slots=ledger.max_slots)
    for slot, kind in sched.initial:
        cluster.fill_and_activate(slot, 0, kind=kind)
    # mixed-kind timeline -> heterogeneity-aware execution: throughput-
    # proportional per-slot batch counts + aggregate-throughput LR rule.
    # The allocator's global batch leaves 2x layout headroom so fast slots
    # can actually take a larger-than-uniform share (rows are capped at
    # per_slot). Homogeneous timelines — and mixed plans priced under
    # "uniform" batching, whose equal-shares semantics IS the plain
    # masked step — keep the masked execution path.
    kinds_seen = {kind for _, kind in sched.initial} \
        | {e.server_kind for e in sched.events if e.kind == "join"}
    allocator = None
    if len(kinds_seen) > 1 and ledger.batching == "dynamic":
        from repro.hetero import DynamicBatchAllocator
        allocator = DynamicBatchAllocator(
            cluster,
            global_batch=max(per_slot * ledger.max_slots // 2, 1),
            cap_per_slot=per_slot,
            base_workers=max(len(sched.initial), 1),
            base_kind=sched.initial[0][1] if sched.initial else "K80")
    rt = ElasticRuntime(model, tcfg, dataset, cluster, ckpt,
                        allocator=allocator, recorder=recorder)
    rt.add_events(sched.events)
    state = init_state(model, tcfg, jax.random.key(seed))
    if sched.executed_steps > 0:
        state = rt.run(state, sched.executed_steps)
    ledger.executed_steps = sched.executed_steps
    ledger.fast_saves = rt.fast_saves
    if rt.metrics_log:
        ledger.final_loss = float(rt.metrics_log[-1]["loss"])
    ledger.accuracy = evaluate_accuracy(model, state.params,
                                        _eval_batch(cfg, dataset))
    return ledger


# ---------------------------------------------------------------------------
# Phase 2b: async-PS staleness replay of the same timeline
# ---------------------------------------------------------------------------

def execute_async_ps(ledger: GymLedger, *, updates: int = 384,
                     seed: int = 0,
                     recorder: Optional[obs.Recorder] = None) -> GymLedger:
    """Replay the membership timeline through ``AsyncPSSimulator``.

    Events are rescaled to PS-update counts (update ``u`` of ``updates``
    corresponds to virtual step ``u / updates * total_steps``) and then
    to the async simulator's own clock by walking the timeline at the
    fleet's aggregate step rate. Fills ``staleness_hist`` and
    ``mean_staleness`` on the ledger, in place.
    """
    import jax
    import jax.numpy as jnp
    from repro.config import OptimizerConfig, ScheduleConfig
    from repro.core.staleness import AsyncPSSimulator, AsyncWorker
    from repro.data.pipeline import Cifar10Like
    from repro.train.step import cross_entropy

    total_updates = updates if ledger.completed else int(
        ledger.vsteps_done / ledger.total_steps * updates)
    if total_updates <= 0:
        ledger.staleness_hist, ledger.mean_staleness = {}, 0.0
        return ledger

    # --- rescale the timeline to the async clock -------------------------
    scale = updates / float(ledger.total_steps)
    workers: List = []
    open_by_cid: Dict[int, object] = {}
    t_async, u_prev = 0.0, 0.0
    agg = 0.0
    for ev in ledger.schedule:
        u = min(ev.vstep * scale, float(updates))
        if agg > 0:
            t_async += max(0.0, u - u_prev) / agg
        u_prev = u
        rate = pricing.SERVER_TYPES[ev.server_kind].steps_per_sec
        if ev.kind == EV_JOIN:
            w = AsyncWorker(wid=len(workers), kind=ev.server_kind,
                            join_t=t_async)
            workers.append(w)
            open_by_cid[ev.slot] = w
            agg += rate
        else:
            w = open_by_cid.pop(ev.slot, None)
            if w is not None:
                w.revoke_t = max(t_async, w.join_t + 1e-6)
                agg = max(0.0, agg - rate)
    if not workers:
        ledger.staleness_hist, ledger.mean_staleness = {}, 0.0
        return ledger

    task = Cifar10Like(seed=seed)
    dim = task.image_size * task.image_size * 3
    key = jax.random.key(seed)
    params = {"w": jax.random.normal(key, (dim, task.num_classes)) * 0.01,
              "b": jnp.zeros((task.num_classes,))}

    def loss(p, batch):
        x = batch["images"].reshape(batch["images"].shape[0], -1)
        return cross_entropy(x @ p["w"] + p["b"], batch["labels"])

    sim = AsyncPSSimulator(
        loss, params,
        OptimizerConfig(name="momentum", lr=0.05, base_workers=1,
                        grad_clip=0),
        ScheduleConfig(kind="constant", warmup_steps=1,
                       total_steps=total_updates))
    res = sim.run(workers, lambda u, w: task.batch(u * 64 + w, 64),
                  total_updates, seed=seed)
    ledger.staleness_hist = res.staleness_histogram()
    ledger.mean_staleness = res.mean_staleness
    rec = recorder if recorder is not None else obs.NULL
    if rec.enabled and ledger.staleness_hist:
        # the async-PS staleness distribution as a metrics histogram
        # (integer staleness values -> integer-ish bucket bounds)
        rec.metrics.histogram(
            "staleness", bounds=(0, 1, 2, 4, 8, 16, 32, 64)
        ).observe_counts(ledger.staleness_hist)
    return ledger
