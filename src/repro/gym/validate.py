"""Differential validation: the gym's trained runs vs the MC engine.

The tolerance contract (documented here, cited by README/ARCHITECTURE,
asserted in ``tests/test_gym.py`` and the CI ``gym-smoke`` job):

- **steps**: mean virtual steps completed (over all trials, failures
  included) agree within ``TOLERANCE["steps_rel"]`` relative error;
- **cost**: mean billed cost over *completed* trials agrees within
  ``TOLERANCE["cost_rel"]`` relative error (spot-path integrals on both
  sides);
- **completion**: completion rates agree within
  ``TOLERANCE["completion_abs"]`` absolute;
- **accuracy**: NOT compared by value — the engine reports the paper's
  calibrated 64K-step accuracy model while the gym reports real eval
  accuracy of a reduced run. Accuracy is instead pinned by *shape*:
  across a sweep of revocation intensities, gym eval accuracy must be
  monotonically non-increasing (within ``TOLERANCE["acc_slack"]``) while
  executed steps are non-increasing — the paper's Table IV / Fig 5
  degradation story, reproduced in real training.

Both sides replay the SAME trace in "zero"-bootstrap mode: each trial
starts at t=0 of the realized timeline and draws its lifetimes from the
trace's windowed empirical distributions, so the two implementations
(the scalar gym fleet model and the vectorized batched engine) see
identical stochastic processes and may differ only through their event
semantics — which is exactly what this module pins.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import PolicyDecision, StaticPolicy
from repro.core.simulator import DEFAULT_TOTAL_STEPS, Summary, simulate_many
from repro.gym.gym import GymLedger, TransientGym, summarize_ledgers
from repro.traces.replay import ReplayContext
from repro.traces.synth import synthetic_trace

TOLERANCE = {
    "steps_rel": 0.10,        # mean virtual steps, all trials
    "cost_rel": 0.15,         # mean billed $, completed trials
    "completion_abs": 0.15,   # completion-rate gap
    "acc_slack": 0.02,        # allowed accuracy rise between intensities
}


@dataclasses.dataclass
class DiffReport:
    """One gym-vs-engine comparison on one (trace, fleet) pair."""
    trace: str
    label: str                    # the static fleet under test
    n_gym: int
    n_engine: int
    gym_summary: Summary
    engine_summary: Summary
    gym_steps_mean: float
    engine_steps_mean: float
    gym_cost_mean: float          # completed trials
    engine_cost_mean: float
    gym_completion: float
    engine_completion: float

    @property
    def steps_rel_err(self) -> float:
        return abs(self.gym_steps_mean - self.engine_steps_mean) \
            / max(self.engine_steps_mean, 1e-9)

    @property
    def cost_rel_err(self) -> float:
        return abs(self.gym_cost_mean - self.engine_cost_mean) \
            / max(self.engine_cost_mean, 1e-9)

    @property
    def completion_gap(self) -> float:
        return abs(self.gym_completion - self.engine_completion)

    def failures(self, tol: Optional[Dict[str, float]] = None) -> List[str]:
        tol = tol or TOLERANCE
        out = []
        if self.steps_rel_err > tol["steps_rel"]:
            out.append(f"steps: gym {self.gym_steps_mean:.0f} vs engine "
                       f"{self.engine_steps_mean:.0f} "
                       f"(rel {self.steps_rel_err:.3f} > "
                       f"{tol['steps_rel']})")
        both_complete = min(self.gym_summary.n_completed,
                            self.engine_summary.n_completed) > 0
        if both_complete and self.cost_rel_err > tol["cost_rel"]:
            out.append(f"cost: gym ${self.gym_cost_mean:.3f} vs engine "
                       f"${self.engine_cost_mean:.3f} "
                       f"(rel {self.cost_rel_err:.3f} > {tol['cost_rel']})")
        if self.completion_gap > tol["completion_abs"]:
            out.append(f"completion: gym {self.gym_completion:.3f} vs "
                       f"engine {self.engine_completion:.3f} "
                       f"(gap {self.completion_gap:.3f} > "
                       f"{tol['completion_abs']})")
        return out

    def ok(self, tol: Optional[Dict[str, float]] = None) -> bool:
        return not self.failures(tol)


def _steps_mean(summary: Summary) -> float:
    """Mean of per-trial ``steps_done`` over ALL trials, failures included."""
    return float(np.mean([r.steps_done for r in summary.results]))


def differential_validate(trace, decision: PolicyDecision, *,
                          total_steps: int = DEFAULT_TOTAL_STEPS,
                          n_gym: int = 32, n_engine: int = 512,
                          seed: int = 0, epoch_s: float = 1800.0,
                          max_h: float = 24.0,
                          batching: str = "dynamic",
                          ledgers: Optional[Sequence[GymLedger]] = None
                          ) -> DiffReport:
    """Replay ``decision`` as a static fleet through BOTH implementations.

    Gym side: ``n_gym`` plan-only episodes (``refill=False`` — provision
    once, revoked slots stay dead, the engine's semantics), one bootstrap
    draw per seed. Engine side: ``simulate_many(..., trace=...)`` on the
    equivalent ``ClusterSpec`` in "zero" mode. Mixed decisions (built
    with ``PolicyDecision.mixed``) validate end-to-end: both sides model
    the same ``batching`` mode via the hetero layer's fleet-rate rule.
    Pass ``ledgers`` to reuse already-run gym episodes (e.g. trained
    ones from the benchmark) instead of planning fresh ones.
    """
    ctx = trace if isinstance(trace, ReplayContext) \
        else ReplayContext(trace, bootstrap="zero")
    if ledgers is None:
        ledgers = [TransientGym(ctx, StaticPolicy(decision),
                                total_steps=total_steps, epoch_s=epoch_s,
                                max_h=max_h, refill=False,
                                seed=seed + i, batching=batching).plan()
                   for i in range(n_gym)]
    gym_sum = summarize_ledgers(list(ledgers))
    gym_steps = float(np.mean([l.vsteps_done for l in ledgers]))

    spec = decision.to_spec(total_steps=total_steps, master_failover=True,
                            batching=batching)
    eng_sum = simulate_many(spec, n_runs=n_engine, seed=seed + 10_000,
                            trace=ctx)
    return DiffReport(
        trace=ctx.trace.name, label=decision.label,
        n_gym=len(ledgers), n_engine=n_engine,
        gym_summary=gym_sum, engine_summary=eng_sum,
        gym_steps_mean=gym_steps,
        engine_steps_mean=_steps_mean(eng_sum),
        gym_cost_mean=gym_sum.cost[0],
        engine_cost_mean=eng_sum.cost[0],
        gym_completion=1.0 - gym_sum.failure_rate,
        engine_completion=1.0 - eng_sum.failure_rate)


# ---------------------------------------------------------------------------
# Revocation-intensity sweep (the Table IV / Fig 5 shape, in real training)
# ---------------------------------------------------------------------------

def intensity_sweep_traces(seed: int = 0,
                           factors: Sequence[float] = (1.0, 0.02, 0.004),
                           kinds: Sequence[str] = ("K80",)) -> List:
    """Synthetic traces of increasing revocation intensity.

    ``factor`` scales every observed lifetime in the trace (smaller =
    revocations come sooner = higher intensity). The same generator seed
    is used throughout so the traces differ ONLY in lifetime scale."""
    out = []
    for f in factors:
        burst = None if f >= 1.0 else {k: [(0.0, 1.0, f)] for k in kinds}
        out.append(synthetic_trace(f"intensity-{f:g}", seed=seed,
                                   kinds=tuple(kinds), price_sigma=0.02,
                                   lifetime_burst=burst))
    return out


def accuracy_intensity_sweep(*, arch: str = "resnet32-cifar10",
                             decision: Optional[PolicyDecision] = None,
                             factors: Sequence[float] = (1.0, 0.02, 0.004),
                             train_steps: int = 96, seed: int = 0,
                             total_steps: int = DEFAULT_TOTAL_STEPS,
                             async_updates: int = 0
                             ) -> List[GymLedger]:
    """Train one gym episode per intensity level; returns the ledgers.

    The monotonicity contract over the result: as the factor shrinks
    (intensity grows), ``executed_steps`` is non-increasing and
    ``accuracy`` is non-increasing within ``TOLERANCE['acc_slack']``.
    """
    decision = decision or PolicyDecision("K80", 4)
    ledgers = []
    for trace in intensity_sweep_traces(seed=seed, factors=factors):
        gym = TransientGym(trace, StaticPolicy(decision),
                           total_steps=total_steps, refill=False, seed=seed)
        ledgers.append(gym.run(arch=arch, train_steps=train_steps,
                               async_updates=async_updates))
    return ledgers


def check_monotone(ledgers: Sequence[GymLedger],
                   acc_slack: Optional[float] = None) -> List[str]:
    """Violations of the intensity-monotonicity contract (empty = ok)."""
    slack = TOLERANCE["acc_slack"] if acc_slack is None else acc_slack
    out = []
    for a, b in zip(ledgers, ledgers[1:]):
        if b.executed_steps > a.executed_steps:
            out.append(f"steps rose {a.executed_steps} -> "
                       f"{b.executed_steps} ({a.trace} -> {b.trace})")
        if b.accuracy > a.accuracy + slack:
            out.append(f"accuracy rose {a.accuracy:.3f} -> "
                       f"{b.accuracy:.3f} ({a.trace} -> {b.trace})")
    return out
