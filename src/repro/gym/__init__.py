"""Trace-driven elastic-training gym — the sim-to-training bridge.

The repo holds two independent implementations of "training on transient
servers": the batched Monte-Carlo/trace/policy layer *predicts* time,
cost, and progress (``core/mc.py``, ``core/policy.py``), while the
elastic runtime *trains* real JAX models under membership churn
(``core/elastic.py``, ``core/staleness.py``). This package closes the
loop: ``TransientGym`` replays one ``Trace`` through a wall-clock fleet
model with a live ``core/policy.py`` policy in the loop, converts the
realized membership timeline into warn/revoke/join events for the masked
elastic runtime and the async-PS simulator, and emits a ledger in the
same ``Summary`` schema as the engine — so ``gym/validate.py`` can pin
simulator predictions against actually-trained runs.
"""
from repro.gym.gym import (EpochRecord, GymLedger, SlotEvent,  # noqa: F401
                           TrainingSchedule, TransientGym,
                           execute_async_ps, execute_masked,
                           summarize_ledgers, training_schedule)
from repro.gym.validate import (DiffReport, TOLERANCE,  # noqa: F401
                                accuracy_intensity_sweep, check_monotone,
                                differential_validate,
                                intensity_sweep_traces)
