"""Deterministic sharded data pipeline with elastic resharding.

The paper's key fault-tolerance bound (C3): when a worker is revoked, the
lost work is at most one batch. We make that bound *constructive*: batches
are a pure function of ``(step, shard_id, num_shards, seed)``, so

- restart from a checkpointed ``step`` replays the exact same stream,
- membership changes just change ``num_shards`` — the surviving workers
  deterministically re-partition the remaining stream with no coordination,
- no batch is ever double-applied or skipped beyond the documented bound.

Synthetic data keeps the container hermetic: token streams come from a
counter-based hash (stateless, no RNG carried between steps); a learnable
Cifar10-like task provides real signal for the staleness/accuracy
reproduction (the class decides a planted linear pattern so small models
can actually learn it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.models import modality

PyTree = Any


def _fold(seed: int, *vals: int) -> np.random.Generator:
    # counter-based: a fresh generator per (seed, step, shard); cheap & pure
    ss = np.random.SeedSequence([seed, *[int(v) & 0x7FFFFFFF for v in vals]])
    return np.random.default_rng(ss)


# ---------------------------------------------------------------------------
# Batch construction (also used by smoke tests; mirrors launch/specs.py)
# ---------------------------------------------------------------------------

def lm_batch_keys(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "vlm":
        return ("tokens", "patch_embeds", "mrope_positions", "labels")
    if cfg.family == "encdec":
        return ("frame_embeds", "tokens", "labels")
    if cfg.family == "resnet":
        return ("images", "labels")
    return ("tokens", "labels")


def make_batch(cfg: ModelConfig, batch: int, seq_len: int, *, seed: int = 0,
               step: int = 0, np_rng: Optional[np.random.Generator] = None
               ) -> Dict[str, jnp.ndarray]:
    """One synthetic batch with the exact input layout of ``cfg``."""
    rng = np_rng or _fold(seed, step)
    V = max(2, cfg.vocab_size)

    if cfg.family == "resnet":
        return {
            "images": jnp.asarray(rng.normal(size=(batch, cfg.image_size,
                                                   cfg.image_size, 3)),
                                  jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.num_classes,
                                               size=(batch,)), jnp.int32),
        }
    if cfg.family == "vlm":
        n_img, n_txt = modality.vlm_split(cfg, seq_len)
        return {
            "tokens": jnp.asarray(rng.integers(0, V, size=(batch, n_txt)),
                                  jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.normal(size=(batch, n_img, cfg.d_model), ).astype(np.float32)
                * 0.02, jnp.dtype(cfg.dtype)),
            "mrope_positions": modality.mrope_positions(cfg, batch, seq_len),
            "labels": jnp.asarray(rng.integers(0, V, size=(batch, seq_len)),
                                  jnp.int32),
        }
    if cfg.family == "encdec":
        ne, nd = modality.encdec_split(cfg, seq_len)
        return {
            "frame_embeds": jnp.asarray(
                rng.normal(size=(batch, ne, cfg.d_model)).astype(np.float32)
                * 0.02, jnp.dtype(cfg.dtype)),
            "tokens": jnp.asarray(rng.integers(0, V, size=(batch, nd)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, V, size=(batch, nd)),
                                  jnp.int32),
        }
    tokens = rng.integers(0, V, size=(batch, seq_len + 1))
    return {
        "tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
        "labels": jnp.asarray(tokens[:, 1:], jnp.int32),
    }


def batch_spec(cfg: ModelConfig, batch: int, seq_len: int
               ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins matching ``make_batch`` (for the dry-run)."""
    sample = jax.eval_shape(
        lambda: make_batch(cfg, batch, seq_len))  # no allocation under eval_shape
    return dict(sample)


# ---------------------------------------------------------------------------
# Sharded dataset
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedDataset:
    """Pure-function dataset: batch = f(step, shard, num_shards, seed)."""
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def shard_batch(self, step: int, shard: int, num_shards: int
                    ) -> Dict[str, jnp.ndarray]:
        if self.global_batch % num_shards:
            raise ValueError(f"global batch {self.global_batch} not divisible "
                             f"by {num_shards} shards")
        per = self.global_batch // num_shards
        rng = _fold(self.seed, step, shard, num_shards)
        return make_batch(self.cfg, per, self.seq_len, np_rng=rng)

    def global_batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = _fold(self.seed, step, 0, 1)
        return make_batch(self.cfg, self.global_batch, self.seq_len, np_rng=rng)


# ---------------------------------------------------------------------------
# A learnable CIFAR-10-like task (planted signal) for accuracy experiments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Cifar10Like:
    """32x32x3 images whose class plants a low-rank directional signal.

    Small models reach high accuracy quickly, and *ordering/staleness of
    updates changes the outcome* — which is exactly the property the
    async-PS accuracy reproduction needs. Deterministic in (seed, step).
    """
    num_classes: int = 10
    image_size: int = 32
    signal: float = 3.0          # strong planted margin: linear models reach
    seed: int = 0                # ~90%+, leaving headroom to SEE staleness
    # per-class channel-mean (color) shift: a random pixel-space direction
    # has ~zero spatial mean, so global-average-pool architectures (the
    # resnet family) never see it — the color component survives pooling.
    # 0.0 keeps the task bit-identical for existing linear-model consumers.
    color_signal: float = 0.0

    def _dirs(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed + 1234)
        d = rng.normal(size=(self.num_classes,
                             self.image_size * self.image_size * 3))
        return (d / np.linalg.norm(d, axis=1, keepdims=True)).astype(np.float32)

    def _colors(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed + 4321)
        c = rng.normal(size=(self.num_classes, 3))
        return (c / np.linalg.norm(c, axis=1, keepdims=True)).astype(np.float32)

    def batch(self, step: int, batch: int, *, shard: int = 0,
              num_shards: int = 1) -> Dict[str, jnp.ndarray]:
        rng = _fold(self.seed, step, shard, num_shards)
        y = rng.integers(0, self.num_classes, size=(batch,))
        x = rng.normal(size=(batch, self.image_size * self.image_size * 3)
                       ).astype(np.float32)
        x = x + self.signal * self._dirs()[y]
        x = x.reshape(batch, self.image_size, self.image_size, 3)
        if self.color_signal:
            x = x + self.color_signal * self._colors()[y][:, None, None, :]
        return {"images": jnp.asarray(x), "labels": jnp.asarray(y, jnp.int32)}

    def eval_batch(self, batch: int = 512) -> Dict[str, jnp.ndarray]:
        return self.batch(10_000_019, batch)   # held-out step namespace
