from repro.data.pipeline import (ShardedDataset, make_batch, batch_spec,
                                 Cifar10Like)  # noqa: F401
