"""Blocked online-softmax attention (flash) — Pallas TPU kernel.

TPU adaptation (not a CUDA port): the grid's last axis iterates KV blocks
*sequentially* ("arbitrary" dimension semantics) while fp32 running-max /
running-sum / accumulator live in VMEM scratch that persists across that
axis — the TPU analogue of a CUDA thread block's shared-memory state. Block
shapes keep the MXU busy: (blk_q x head_dim) @ (head_dim x blk_k) contractions
with blk_q/blk_k multiples of 128 and head_dim padded to lanes by Mosaic.

Supports causal masking, GQA (q-head -> kv-head via the k/v index_map, no
materialized head broadcast), and gemma3-style sliding windows. The window
is a *traced scalar* (SMEM) because gemma3 scans over layers with per-layer
windows — one compiled kernel serves local and global layers. Fully-masked
KV blocks are skipped with ``pl.when`` — for causal masks that's ~2x fewer
MXU passes, and for sliding windows the skip makes attention O(S*W).

VMEM working set per grid step (bf16 in, fp32 scratch):
    q: blk_q*D*2  k,v: blk_k*D*2*2  acc: blk_q*D*4  m,l: blk_q*128*4*2
    (blk_q=blk_k=256, D=128: ~0.7 MB — far under the ~16 MB VMEM budget,
     leaving room for Mosaic's double buffering of the k/v streams.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = float("-inf")
LANES = 128


def _attn_kernel(win_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                 *, sm_scale: float, causal: bool,
                 blk_q: int, blk_k: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    win = win_ref[0]                                       # <=0 means global

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * blk_q
    k_start = ki * blk_k

    # Block-level skip: entirely above the diagonal (causal) or entirely
    # below the window. Row/col offsets inside the block are handled by the
    # element mask; this predicate only prunes whole blocks.
    run = k_start < seq_k
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + blk_q - 1)
    run = jnp.logical_and(
        run, jnp.logical_or(win <= 0,
                            k_start + blk_k - 1 >= q_start - win + 1))

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (blk_q, D)
        k = k_ref[0, 0].astype(jnp.float32)                 # (blk_k, D)
        v = v_ref[0, 0].astype(jnp.float32)                 # (blk_k, D)
        # Ragged tail: rows past seq_k are padding (undefined contents) —
        # zero them so 0-weight x garbage can't poison the accumulator.
        kv_valid = (k_start + jax.lax.broadcasted_iota(
            jnp.int32, (blk_k, 1), 0)) < seq_k
        k = jnp.where(kv_valid, k, 0.0)
        v = jnp.where(kv_valid, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                    # (blk_q, blk_k)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_k                                # ragged tail
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        mask = jnp.logical_and(
            mask, jnp.where(win > 0, k_pos > q_pos - win, True))
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                               # (blk_q, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # all-masked rows keep m = -inf; exp(-inf - -inf) guarded to 0
        p = jnp.exp(jnp.where(m_new == NEG_INF, NEG_INF, s - m_new))
        alpha = jnp.exp(jnp.where(m_new == NEG_INF, 0.0, m_prev - m_new))
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "blk_q", "blk_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=0,
                    sm_scale: float | None = None,
                    blk_q: int = 256, blk_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D). Returns (B, H, Sq, D).

    H must be a multiple of KV (GQA); q-head h reads kv-head h // (H//KV).
    ``window`` may be a python int or a traced int32 scalar (<=0 = global).
    """
    B, H, Sq, D = q.shape
    _, KV, Sk, _ = k.shape
    assert H % KV == 0, (H, KV)
    group = H // KV
    if sm_scale is None:
        sm_scale = D ** -0.5
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    nq = pl.cdiv(Sq, blk_q)
    nk = pl.cdiv(Sk, blk_k)
    win = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(
        _attn_kernel, sm_scale=sm_scale, causal=causal,
        blk_q=blk_q, blk_k=blk_k, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, blk_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), jnp.float32),       # acc
            pltpu.VMEM((blk_q, LANES), jnp.float32),   # running max
            pltpu.VMEM((blk_q, LANES), jnp.float32),   # running sum
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(win, q, k, v)
