"""jit'd wrapper: impl selection + layout adaptation for model code.

Model code holds activations as (B, S, H, D); the kernel wants head-major
(B, H, S, D) so a q-block is one contiguous VMEM tile. The transpose pair
is fused by XLA into the surrounding projections (verified in the dry-run
HLO: no standalone transpose op survives).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.obs.profiling import annotate_span


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              sm_scale: float | None = None, impl: str = "pallas",
              blk_q: int = 256, blk_k: int = 256) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    with annotate_span(f"kernel.flash_attention.{impl}"):
        if impl == "xla":
            out = attention_ref(qt, kt, vt, causal=causal, window=window,
                                sm_scale=sm_scale)
        elif impl == "pallas":
            out = flash_attention(qt, kt, vt, causal=causal, window=window,
                                  sm_scale=sm_scale, blk_q=blk_q,
                                  blk_k=blk_k, interpret=_on_cpu())
        else:
            raise ValueError(f"unknown impl {impl!r}")
    return out.transpose(0, 2, 1, 3)
