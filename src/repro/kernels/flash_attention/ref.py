"""Pure-jnp oracle for the flash-attention kernel (no blocking, fp32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  sm_scale: float | None = None) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    _, KV, Sk, _ = k.shape
    group = H // KV
    if sm_scale is None:
        sm_scale = D ** -0.5
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * sm_scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)          # fully-masked rows -> 0
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
