"""RWKV-6 (Finch) WKV recurrence — chunked Pallas TPU kernel.

The defining recurrence (per head, state S in R^{Dk x Dv}):

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          w_t in (0,1): data-dependent

A naive port is a length-S sequential loop — dead on the MXU. The TPU form
expands each chunk in *pairwise log-decay space*: with L_t = sum_{s<=t}
log w_t (elementwise, <= 0) the contribution of token j to token t>j is

    A[t, j] = sum_d  r[t,d] k[j,d] exp(L_{t-1,d} - L_{j,d})

where every exponent is <= 0 (decay), so unlike the classic k/W
"de-decayed keys" trick there is NO overflow for any data-dependent w —
the (L, L, D) decay tensor trades VMEM (L^2 D fp32; 1 MB at L=D=64) for
unconditional fp32 safety. Chunk -> chunk carries only S in VMEM scratch
across the sequential grid axis, exactly like the SSD kernel.

Per grid step:  A @ v, (r * exp(L_excl)) @ S, and the rank-L state update
(k * exp(L_last - L))^T @ v — three MXU contractions per chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                  o_ref, sout_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)              # (L, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)              # decays in (0, 1)
    u = u_ref[0].astype(jnp.float32)                 # (D,)

    logw = jnp.log(w)                                # <= 0
    lw = jnp.cumsum(logw, axis=0)                    # inclusive  (L, D)
    lwx = lw - logw                                  # exclusive: L_{t-1}

    # pairwise intra-chunk attention with per-channel decay
    dec = jnp.exp(lwx[:, None, :] - lw[None, :, :])  # (L, L, D); tril <= 1
    a = jnp.einsum("td,jd,tjd->tj", r, k, dec)       # strict lower + diag junk
    t_idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(t_idx > j_idx, a, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)      # bonus term at j == t
    a = a + jnp.diag(diag)

    o_intra = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state = state_ref[...]                           # (Dk, Dv) pre-chunk
    o_state = jax.lax.dot_general(r * jnp.exp(lwx), state,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    o_ref[0, 0, ...] = (o_intra + o_state).astype(o_ref.dtype)

    last = lw[-1]                                    # (D,)
    kd = k * jnp.exp(last[None, :] - lw)             # (L, D), factors <= 1
    state_ref[...] = jnp.exp(last)[:, None] * state + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _emit_state():
        sout_ref[0, 0, ...] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, s0: jax.Array | None = None, *, chunk: int = 64,
               interpret: bool = False):
    """r/k/v/w: (B, H, S, D) fp32 (w = per-step decay in (0,1));
    u: (H, D); s0 optional initial state (B, H, D, D) fp32.
    Returns (o (B, H, S, D) fp32, final_state (B, H, D, D) fp32)."""
    B, H, S, D = r.shape
    L = min(chunk, S)
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    nc = S // L
    if s0 is None:
        s0 = jnp.zeros((B, H, D, D), jnp.float32)

    kernel = functools.partial(_rwkv6_kernel, chunk=L)
    blk = pl.BlockSpec((1, 1, L, D), lambda b, h, c: (b, h, c, 0))
    sblk = pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[blk, blk, blk, blk,
                  pl.BlockSpec((1, D), lambda b, h, c: (h, 0)), sblk],
        out_specs=(blk, sblk),
        out_shape=(jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, D, D), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
