from repro.kernels.rwkv6.ops import wkv  # noqa: F401
from repro.kernels.rwkv6.kernel import rwkv6_scan  # noqa: F401
from repro.kernels.rwkv6.ref import rwkv6_ref  # noqa: F401
