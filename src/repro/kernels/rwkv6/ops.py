"""jit'd wrapper: model layout (B, S, H, D) <-> kernel head-major layout."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6.kernel import rwkv6_scan
from repro.kernels.rwkv6.ref import rwkv6_ref
from repro.obs.profiling import annotate_span


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
        u: jax.Array, s0: jax.Array | None = None, *,
        chunk: int = 64, impl: str = "pallas"):
    """r/k/v/w: (B, S, H, D); u: (H, D); s0 (B, H, D, D) optional.
    Returns (o (B, S, H, D) fp32, final state (B, H, D, D))."""
    rt, kt, vt, wt = (a.transpose(0, 2, 1, 3) for a in (r, k, v, w))
    with annotate_span(f"kernel.rwkv6.{impl}"):
        if impl == "xla":
            out, state = rwkv6_ref(rt, kt, vt, wt, u, s0)
        elif impl == "pallas":
            out, state = rwkv6_scan(rt, kt, vt, wt, u, s0, chunk=chunk,
                                    interpret=_on_cpu())
        else:
            raise ValueError(f"unknown impl {impl!r}")
    return out.transpose(0, 2, 1, 3), state
