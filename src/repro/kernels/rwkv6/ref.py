"""Sequential oracle: the per-token WKV recurrence (rwkv.py's _wkv_scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, s0: jax.Array | None = None):
    """r/k/v/w: (B, H, S, D); u: (H, D); s0 optional (B, H, D, D).
    Returns (o (B, H, S, D) fp32, final state (B, H, D, D))."""
    B, H, S, D = r.shape
    r32, k32, v32, w32 = (a.astype(jnp.float32) for a in (r, k, v, w))
    u32 = u.astype(jnp.float32)

    def step(S_state, t):
        rt, kt, vt, wt = r32[:, :, t], k32[:, :, t], v32[:, :, t], w32[:, :, t]
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,Dk,Dv)
        o = jnp.einsum("bhk,bhkv->bhv", rt,
                       S_state + u32[None, :, :, None] * kv)
        S_state = wt[..., :, None] * S_state + kv
        return S_state, o

    S0 = (jnp.zeros((B, H, D, D), jnp.float32) if s0 is None
          else s0.astype(jnp.float32))
    S_fin, os = jax.lax.scan(step, S0, jnp.arange(S))
    return os.transpose(1, 2, 0, 3), S_fin                # (B,H,S,D)
