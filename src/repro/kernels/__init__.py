"""Pallas TPU kernels for the perf-critical compute paths.

Each subpackage: kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper + layout adaptation + impl selection),
ref.py (pure-jnp oracle the tests sweep against in interpret mode).

flash_attention   blocked online-softmax fwd; causal, GQA, traced sliding
                  windows (gemma3's per-layer scan), block skipping
decode_attention  single-token decode vs long KV caches; length + window
                  masking; sequential split-K analogue with VMEM scratch
ssd_scan          Mamba-2 chunked state-space dual scan (zamba2 backbone)
rwkv6             RWKV-6 WKV recurrence, log-space pairwise-decay chunking
                  with exact state carry (overflow-safe for any w)

The paper itself has no kernel-level contribution (its layer is the
cluster runtime); these are the substrate a production framework needs,
selected per-arch via cfg.attn_impl / ssm_impl / rwkv_impl = "pallas".
"""
