"""Version-adaptive shim over the jax APIs the Pallas kernels need.

jax renamed two things the kernels depend on between the 0.4.x line this
container pins and the 0.5 line the kernels were written against:

    pltpu.TPUCompilerParams   (0.4.x)  ->  pltpu.CompilerParams   (>=0.5)
    jax.experimental.shard_map.shard_map (0.4.x, check_rep=)
                              ->  jax.shard_map (>=0.5, check_vma=)

Every kernel subpackage (and the shard_map MoE paths in models/ffn.py)
routes through this module instead of touching either spelling directly,
so the same source compiles on both toolchains. Resolution happens at
*call* time, not import time: importing ``repro.kernels`` can never raise
an ``AttributeError`` on a jax we don't know — an unresolvable API
surfaces as an explicit :class:`UnsupportedJaxError` with both spellings
named, exactly when (and only when) a kernel is actually launched.

The ``pltpu_module`` / ``jax_module`` injection points exist for the
compat matrix tests, which sweep every API-presence combination without
needing three jax installs.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional


class UnsupportedJaxError(RuntimeError):
    """The installed jax exposes neither the old nor the new spelling of a
    required API. Carries both names so the failure is actionable."""


# ---------------------------------------------------------------------------
# pltpu.CompilerParams vs pltpu.TPUCompilerParams
# ---------------------------------------------------------------------------

def compiler_params_cls(pltpu_module: Optional[Any] = None):
    """The Mosaic compiler-params class under whichever name exists."""
    if pltpu_module is None:
        from jax.experimental.pallas import tpu as pltpu_module
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu_module, name, None)
        if cls is not None:
            return cls
    raise UnsupportedJaxError(
        "installed jax exposes neither pallas.tpu.CompilerParams (jax>=0.5) "
        "nor pallas.tpu.TPUCompilerParams (jax 0.4.x); the Pallas kernels "
        "cannot build their grids on this toolchain")


def compiler_params(pltpu_module: Optional[Any] = None, **kwargs):
    """Instantiate compiler params, e.g.
    ``compat.compiler_params(dimension_semantics=("parallel", "arbitrary"))``.
    """
    return compiler_params_cls(pltpu_module)(**kwargs)


# ---------------------------------------------------------------------------
# jax.shard_map vs jax.experimental.shard_map.shard_map
# ---------------------------------------------------------------------------

def shard_map_fn(jax_module: Optional[Any] = None):
    """The shard_map callable under whichever spelling exists."""
    if jax_module is None:
        import jax as jax_module
    fn = getattr(jax_module, "shard_map", None)
    if fn is not None:
        return fn
    exp = getattr(jax_module, "experimental", None)
    mod = getattr(exp, "shard_map", None) if exp is not None else None
    if mod is None and exp is not None:
        try:  # submodule may simply not be imported yet
            import importlib
            mod = importlib.import_module(
                jax_module.__name__ + ".experimental.shard_map")
        except ImportError:
            mod = None
    fn = getattr(mod, "shard_map", None)
    if fn is not None:
        return fn
    raise UnsupportedJaxError(
        "installed jax exposes neither jax.shard_map (jax>=0.5) nor "
        "jax.experimental.shard_map.shard_map (jax 0.4.x); the expert-"
        "parallel MoE paths cannot run on this toolchain")


def shard_map(f, mesh, *, in_specs, out_specs, check_vma: Optional[bool] = None,
              jax_module: Optional[Any] = None):
    """Call shard_map with replication checking spelled for the installed
    jax: ``check_vma`` (>=0.5) is translated to ``check_rep`` (0.4.x); a
    signature with neither drops the flag rather than erroring."""
    fn = shard_map_fn(jax_module)
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if check_vma is not None:
        try:
            params = inspect.signature(fn).parameters
        except (TypeError, ValueError):
            params = {}
        if "check_vma" in params:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in params:
            kwargs["check_rep"] = check_vma
    return fn(f, **kwargs)
