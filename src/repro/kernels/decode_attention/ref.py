"""Pure-jnp oracle for decode attention (full softmax, length-masked)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array, *, window=0,
                         sm_scale: float | None = None) -> jax.Array:
    """q: (B, H, D); k/v: (B, KV, S, D); lengths: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    _, KV, S, _ = k.shape
    group = H // KV
    if sm_scale is None:
        sm_scale = D ** -0.5
    win = jnp.asarray(window, jnp.int32)
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kf) * sm_scale
    pos = jnp.arange(S)[None, None, :]
    mask = pos < lengths[:, None, None]
    mask &= jnp.where(win > 0, pos >= lengths[:, None, None] - win, True)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhk,bhkd->bhd", p, vf).astype(q.dtype)
