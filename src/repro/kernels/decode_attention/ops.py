"""jit'd wrapper for decode attention with impl selection.

Model layout: q (B, 1, H, D) one new token; cache (B, S, KV, D). The
wrapper squeezes/transposes to the kernel's head-major layout.
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.obs.profiling import annotate_span


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def decode_attend(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  lengths: jax.Array, *, window=0, impl: str = "pallas",
                  blk_k: int = 512) -> jax.Array:
    """q: (B, 1, H, D); k/v cache: (B, S, KV, D); lengths (B,) ->
    (B, 1, H, D)."""
    qs = q[:, 0]                                   # (B, H, D)
    kt = k_cache.transpose(0, 2, 1, 3)             # (B, KV, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    with annotate_span(f"kernel.decode_attention.{impl}"):
        if impl == "xla":
            out = decode_attention_ref(qs, kt, vt, lengths, window=window)
        elif impl == "pallas":
            out = decode_attention(qs, kt, vt, lengths, window=window,
                                   blk_k=blk_k, interpret=_on_cpu())
        else:
            raise ValueError(f"unknown impl {impl!r}")
    return out[:, None]
