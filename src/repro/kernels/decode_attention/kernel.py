"""Single-token decode attention vs. a long KV cache — Pallas TPU kernel.

GPU split-K decode parallelizes one query's KV reduction across SMs and
merges partial softmaxes in a second pass. The TPU adaptation streams KV
blocks *sequentially* through VMEM (grid last axis "arbitrary") while the
online-softmax state rides in VMEM scratch — same O(S) HBM traffic, no
merge pass, and the block stream is double-buffered by Mosaic so the
kernel is HBM-bandwidth-bound, which is the roofline for decode.

Decode is memory-bound: arithmetic intensity ~ 2 flops/byte of KV, so the
only lever is moving KV bytes at line rate — hence blocks shaped
(blk_k x D) with D on lanes, and all q heads of one kv group processed
against each streamed KV block (the GQA reuse is free: q is tiny).

The cache may be longer than the valid prefix; ``lengths`` masks per batch
row. Grid: (B, KV, nk). Each step does a (G x D) @ (D x blk_k) MXU pass
where G = heads-per-kv-group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = float("-inf")
LANES = 128


def _decode_kernel(len_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   sm_scale: float, blk_k: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    length = len_ref[pl.program_id(0)]
    win = win_ref[0]                                 # <=0 means full history

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ki * blk_k

    run = k_start < length                           # skip fully-invalid blocks
    run = jnp.logical_and(                           # and blocks below window
        run, jnp.logical_or(win <= 0, k_start + blk_k - 1 >= length - win))

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (blk_k, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (blk_k, D)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_k, 1), 0)
        valid = k_pos < length
        valid = jnp.logical_and(
            valid, jnp.where(win > 0, k_pos >= length - win, True))
        k = jnp.where(valid, k, 0.0)
        v = jnp.where(valid, v, 0.0)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                             # (G, blk_k)
        s = jnp.where(valid.T, s, NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(jnp.where(m_new == NEG_INF, 0.0, m_prev - m_new))
        l_ref[...] = jnp.broadcast_to(alpha * l_prev
                                      + jnp.sum(p, -1, keepdims=True),
                                      l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0, 0, ...] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                            ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("sm_scale", "blk_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, window=0,
                     sm_scale: float | None = None, blk_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D) one token; k/v: (B, KV, S, D); lengths: (B,) int32.

    Valid cache positions for row b are [0, lengths[b]); a positive
    ``window`` (traced or static) restricts to the last ``window`` of them.
    Returns (B, H, D).
    """
    B, H, D = q.shape
    _, KV, S, _ = k.shape
    assert H % KV == 0
    G = H // KV
    if sm_scale is None:
        sm_scale = D ** -0.5
    blk_k = min(blk_k, S)
    nk = pl.cdiv(S, blk_k)
    qg = q.reshape(B, KV, G, D)
    win = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale, blk_k=blk_k)

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # lengths, whole array
            pl.BlockSpec(memory_space=pltpu.SMEM),     # window scalar
            pl.BlockSpec((1, 1, G, D), lambda b, g, ki: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, blk_k, D), lambda b, g, ki: (b, g, ki, 0)),
            pl.BlockSpec((1, 1, blk_k, D), lambda b, g, ki: (b, g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, g, ki: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, win, qg, k, v)
    return out.reshape(B, H, D)
