"""Sequential-recurrence oracle for the SSD kernel.

Proves the chunked/dual form against the defining per-token recurrence:

    S_t = exp(dA_t) S_{t-1} + B_t (x) xdt_t        (per head, (N, P) state)
    y_t = C_t . S_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xdt: jax.Array, Bc: jax.Array, Cc: jax.Array, dA: jax.Array
            ) -> jax.Array:
    """xdt (B,H,S,P); Bc/Cc (B,S,N); dA (B,H,S) -> y (B,H,S,P)."""
    B, H, S, P = xdt.shape
    N = Bc.shape[-1]
    xdt32 = xdt.astype(jnp.float32)
    B32 = Bc.astype(jnp.float32)
    C32 = Cc.astype(jnp.float32)
    dA32 = dA.astype(jnp.float32)

    def step(state, t):
        # state: (B, H, N, P)
        decay = jnp.exp(dA32[:, :, t])                       # (B, H)
        outer = jnp.einsum("bn,bhp->bhnp", B32[:, t], xdt32[:, :, t])
        state = state * decay[:, :, None, None] + outer
        y = jnp.einsum("bn,bhnp->bhp", C32[:, t], state)
        return state, y

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, state0, jnp.arange(S))
    return ys.transpose(1, 2, 0, 3).astype(xdt.dtype)        # (B,H,S,P)
