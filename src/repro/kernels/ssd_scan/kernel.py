"""Mamba-2 SSD chunked scan — Pallas TPU kernel (zamba2 backbone hot loop).

The SSD duality: within a chunk of length Q the recurrence is a lower-
triangular attention-like matmul (MXU work); across chunks only an
(N x P) state is carried. The TPU mapping runs the chunk axis as the
grid's sequential ("arbitrary") dimension with the carried state in fp32
VMEM scratch, so the HLO has ONE chunk body regardless of sequence length
and state never round-trips to HBM — the GPU version's inter-SM state
handoff becomes a scratch register file, which is the correct analogue.

Per grid step, fp32:
    cum   = cumsum(dA)                         (Q,)    decay integrals
    dec   = tril(exp(cum_i - cum_j))           (Q, Q)
    att   = (C B^T) * dec                      (Q, Q)  MXU
    y     = att @ xdt + exp(cum) * (C @ state) (Q, P)  MXU x2
    state = exp(cum_Q) * state + B^T diag(exp(cum_Q - cum)) xdt

All exponents are <= 0 (decays), so the chunk math is overflow-safe
without the max-subtraction tricks the attention kernels need.

B/C are G=1 (single group, shared across heads): their index_map ignores
the head grid axis, so the same (Q x N) block is reused by all H heads —
an HBM-traffic win the fused-per-head GPU layout doesn't get.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _ssd_kernel(xdt_ref, b_ref, c_ref, da_ref, y_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0, 0].astype(jnp.float32)          # (Q, P)
    B = b_ref[0].astype(jnp.float32)                 # (Q, N)
    C = c_ref[0].astype(jnp.float32)                 # (Q, N)
    dA = da_ref[0, 0].astype(jnp.float32)            # (Q,)

    cum = jnp.cumsum(dA)                             # (Q,)
    logdec = cum[:, None] - cum[None, :]             # (Q, Q), tril <= 0
    tri = jax.lax.broadcasted_iota(jnp.int32, logdec.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, logdec.shape, 1)
    dec = jnp.where(tri, jnp.exp(logdec), 0.0)

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    att = cb * dec
    y_intra = jax.lax.dot_general(att, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    state = state_ref[...]                           # (N, P) pre-chunk
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Q, P)

    y_ref[0, 0, ...] = (y_intra + y_inter).astype(y_ref.dtype)

    last = cum[-1]
    sdec = jnp.exp(last - cum)                       # (Q,) <= 1
    state_ref[...] = jnp.exp(last) * state + jax.lax.dot_general(
        B, sdec[:, None] * xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (N, P)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xdt: jax.Array, Bc: jax.Array, Cc: jax.Array, dA: jax.Array, *,
             chunk: int = 128, interpret: bool = False) -> jax.Array:
    """Chunked SSD. Head-major layouts:

    xdt (B, H, S, P) = x * dt;  Bc/Cc (B, S, N) single-group;
    dA (B, H, S) = dt * a (<= 0). Returns y (B, H, S, P) fp32-accumulated.
    """
    B, H, S, P = xdt.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, Bc, Cc, dA)
