"""jit'd wrapper: model layout (B, S, H, P) <-> kernel head-major layout."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.obs.profiling import annotate_span


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def ssd(xdt: jax.Array, Bc: jax.Array, Cc: jax.Array, dA: jax.Array, *,
        chunk: int = 128, impl: str = "pallas") -> jax.Array:
    """xdt (B,S,H,P); Bc/Cc (B,S,N); dA (B,S,H) -> y (B,S,H,P)."""
    xt = xdt.transpose(0, 2, 1, 3)
    dt = dA.transpose(0, 2, 1)
    with annotate_span(f"kernel.ssd_scan.{impl}"):
        if impl == "xla":
            out = ssd_ref(xt, Bc, Cc, dt)
        elif impl == "pallas":
            out = ssd_scan(xt, Bc, Cc, dt, chunk=chunk, interpret=_on_cpu())
        else:
            raise ValueError(f"unknown impl {impl!r}")
    return out.transpose(0, 2, 1, 3)
