"""Synthetic inference *request* traces: the serving-side workload object.

``traces/synth.py`` makes the supply side (spot prices, revocations) a
first-class timeline; this module does the same for the demand side. A
``RequestTrace`` is a deterministic, seeded arrival process the serving
stack replays: the engine tests, ``launch/serve.py``, and
``benchmarks/serve_frontier.py`` all consume the identical workload, so
latency/cost numbers are comparable across runs and platforms.

The arrival process is a non-homogeneous Poisson process sampled by
thinning: a base rate shaped by a **diurnal** sinusoid (the day/night
swing every serving paper measures) times multiplicative **burst**
windows (flash-crowd spikes, the arrival analogue of a revocation
storm). Prompt/output lengths are lognormal-ish integer draws, and each
request carries SLO metadata (class label, relative deadline) so the
SLO queue has something real to order by.

Serialization mirrors ``traces/schema.py``: one JSONL header line with
meta, one event per line, lossless round-trip.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

# (t0_frac, t1_frac, factor) — multiplicative rate windows, as in synth.py
Regime = Tuple[float, float, float]

_JSONL_VERSION = 1


@dataclasses.dataclass(frozen=True)
class RequestEvent:
    """One arrival: when, how big, and under what SLO."""
    t_s: float                    # arrival time on the trace clock
    rid: int
    prompt_len: int
    max_new_tokens: int
    slo: str = "default"          # SLO class label
    priority: int = 0             # lower sorts first
    deadline_rel_s: float = math.inf   # deadline relative to arrival

    def to_json(self) -> dict:
        d = {"t_s": self.t_s, "rid": self.rid,
             "prompt_len": self.prompt_len,
             "max_new_tokens": self.max_new_tokens}
        if self.slo != "default":
            d["slo"] = self.slo
        if self.priority:
            d["priority"] = self.priority
        if math.isfinite(self.deadline_rel_s):
            d["deadline_rel_s"] = self.deadline_rel_s
        return d

    @staticmethod
    def from_json(d: dict) -> "RequestEvent":
        return RequestEvent(t_s=float(d["t_s"]), rid=int(d["rid"]),
                            prompt_len=int(d["prompt_len"]),
                            max_new_tokens=int(d["max_new_tokens"]),
                            slo=d.get("slo", "default"),
                            priority=int(d.get("priority", 0)),
                            deadline_rel_s=float(d.get("deadline_rel_s",
                                                       math.inf)))


@dataclasses.dataclass(frozen=True)
class RequestTrace:
    name: str
    horizon_s: float
    events: Tuple[RequestEvent, ...]     # sorted by t_s
    seed: Optional[int] = None

    def __post_init__(self):
        ts = [e.t_s for e in self.events]
        if ts != sorted(ts):
            raise ValueError("request events must be sorted by t_s")

    @property
    def n_requests(self) -> int:
        return len(self.events)

    def rate_per_s(self) -> float:
        if self.horizon_s <= 0:
            return 0.0
        return len(self.events) / self.horizon_s

    # -- serialization (same header+lines shape as traces/schema.py) --------
    def to_jsonl(self, path: str) -> str:
        header = {"jsonl_version": _JSONL_VERSION, "name": self.name,
                  "horizon_s": self.horizon_s, "seed": self.seed,
                  "n_events": len(self.events)}
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev.to_json()) + "\n")
        return path

    @staticmethod
    def from_jsonl(path: str) -> "RequestTrace":
        with open(path) as f:
            header = json.loads(next(f))
            if header.get("jsonl_version") != _JSONL_VERSION:
                raise ValueError(
                    f"unsupported request-trace version in {path}: "
                    f"{header.get('jsonl_version')!r}")
            events = tuple(RequestEvent.from_json(json.loads(line))
                           for line in f if line.strip())
        return RequestTrace(name=header["name"],
                            horizon_s=float(header["horizon_s"]),
                            events=events, seed=header.get("seed"))


def _regime_factor(t: np.ndarray, horizon_s: float,
                   regimes: Sequence[Regime]) -> np.ndarray:
    f = np.ones_like(t)
    for t0, t1, factor in regimes:
        f = np.where((t >= t0 * horizon_s) & (t < t1 * horizon_s),
                     f * factor, f)
    return f


# SLO classes: (label, priority, relative deadline, sampling weight).
# interactive = chat-like traffic with a tight deadline; batch = offline
# work that tolerates queueing — what admission control sheds first.
SLO_CLASSES = (("interactive", 0, 30.0, 0.6),
               ("standard", 1, 120.0, 0.3),
               ("batch", 2, math.inf, 0.1))


def synthetic_request_trace(name: str = "serve-diurnal", *, seed: int = 0,
                            horizon_s: float = 600.0,
                            base_rate_per_s: float = 0.5,
                            diurnal_amplitude: float = 0.6,
                            diurnal_period_s: Optional[float] = None,
                            bursts: Sequence[Regime] = (),
                            prompt_len_mean: int = 12,
                            max_prompt_len: int = 64,
                            new_tokens_mean: int = 12,
                            max_new_tokens: int = 48,
                            slo_classes=SLO_CLASSES) -> RequestTrace:
    """Deterministic non-homogeneous Poisson arrivals by thinning.

    rate(t) = base * (1 + A*sin(2*pi*t/period)) * burst_factor(t), with
    candidate arrivals drawn at the peak rate and accepted with
    probability rate(t)/peak — the standard thinning construction, so the
    accepted set is an exact draw from the shaped process. ``bursts`` are
    fractional-horizon windows multiplying the rate (a flash crowd),
    mirroring ``synth.py``'s regime windows on the supply side.
    """
    if not (0.0 <= diurnal_amplitude < 1.0):
        raise ValueError(f"diurnal_amplitude must be in [0, 1), "
                         f"got {diurnal_amplitude}")
    rng = np.random.default_rng(seed)
    period = diurnal_period_s if diurnal_period_s is not None else horizon_s
    peak = base_rate_per_s * (1.0 + diurnal_amplitude) \
        * max([f for _, _, f in bursts], default=1.0)
    n_cand = rng.poisson(peak * horizon_s)
    t = np.sort(rng.uniform(0.0, horizon_s, size=n_cand))
    rate = base_rate_per_s * (
        1.0 + diurnal_amplitude * np.sin(2.0 * math.pi * t / period))
    rate = rate * _regime_factor(t, horizon_s, bursts)
    keep = rng.uniform(0.0, peak, size=n_cand) < rate
    t = t[keep]

    n = len(t)
    plen = np.clip(np.round(rng.lognormal(math.log(max(prompt_len_mean, 1)),
                                          0.5, size=n)),
                   1, max_prompt_len).astype(int)
    ntok = np.clip(np.round(rng.lognormal(math.log(max(new_tokens_mean, 1)),
                                          0.6, size=n)),
                   1, max_new_tokens).astype(int)
    weights = np.array([w for _, _, _, w in slo_classes], dtype=float)
    cls = rng.choice(len(slo_classes), size=n, p=weights / weights.sum())

    events = []
    for i in range(n):
        label, prio, ddl, _ = slo_classes[int(cls[i])]
        events.append(RequestEvent(t_s=float(t[i]), rid=i,
                                   prompt_len=int(plen[i]),
                                   max_new_tokens=int(ntok[i]),
                                   slo=label, priority=prio,
                                   deadline_rel_s=ddl))
    return RequestTrace(name=name, horizon_s=horizon_s,
                        events=tuple(events), seed=seed)
