"""Trace subsystem: recorded/synthetic transient-market timelines.

The paper's redesign call is that transient conditions are *dynamic*:
prices drift, revocation intensity comes in bursts, capacity appears and
disappears. The static closed-form lifetime mixtures in
``core/transient.py`` average all of that away. This package makes the
timeline a first-class object:

schema   ``Trace``/``TraceEvent`` — timestamped per-zone, per-type spot
         price updates, revocation observations, and capacity changes,
         with lossless JSONL and npz round-trip serialization.
synth    deterministic generators calibrated to the paper's Fig 3
         lifetime mixtures plus a mean-reverting (OU) spot-price process.
replay   vectorized trace playback for the batched MC engine
         (``ReplayContext``): bootstrap-resampled lifetime windows and
         piecewise-constant price integration, keeping the trial axis an
         array axis.
requests the demand-side twin: seeded inference *request* traces
         (diurnal + bursty Poisson arrivals with SLO classes) that the
         serving engine, ``launch/serve.py``, and
         ``benchmarks/serve_frontier.py`` replay.

``simulate_many(..., trace=...)`` and the policy layer
(``core/policy.py``) consume these.
"""
from repro.traces.schema import (EVENT_KINDS, Trace,  # noqa: F401
                                 TraceEvent)
from repro.traces.synth import (default_trace_suite,  # noqa: F401
                                synthetic_trace, trace_from_model)
from repro.traces.replay import ReplayContext  # noqa: F401
from repro.traces.requests import (RequestEvent, RequestTrace,  # noqa: F401
                                   synthetic_request_trace)


def load_trace(spec: str, seed: int = 0) -> Trace:
    """Resolve a CLI trace argument: a file path or a synthetic name.

    ``*.jsonl`` / ``*.npz`` load the recorded file; ``calm`` /
    ``volatile`` / ``bursty`` name the deterministic synthetic suite
    (``synth.default_trace_suite``).
    """
    if spec.endswith(".jsonl"):
        return Trace.from_jsonl(spec)
    if spec.endswith(".npz"):
        return Trace.from_npz(spec)
    suite = {t.name: t for t in default_trace_suite(seed)}
    if spec in suite:
        return suite[spec]
    raise ValueError(f"unknown trace {spec!r}: expected a .jsonl/.npz path "
                     f"or one of {sorted(suite)}")
