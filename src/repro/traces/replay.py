"""Vectorized trace replay for the batched Monte-Carlo engine.

``ReplayContext`` turns a ``Trace`` into the two things the engine needs,
both as array programs over the trial axis:

1. **Lifetimes** — instead of sampling the closed-form mixtures, trials
   bootstrap-resample the trace's observed revocation lifetimes. The
   horizon is split into ``n_windows`` equal windows, each holding the
   empirical lifetime distribution of the revocations observed inside it
   — this is what preserves the trace's time-correlation (a burst window
   has short lifetimes). A draw for a server activating at time ``t`` is
   conditioned on the window containing ``t``, so a revocation storm
   hits every trial that provisions during the storm. Windows with too
   few observations fall back to the kind's full observation vector;
   kinds with no observations at all fall back to the calibrated mixture
   (``transient.LIFETIMES``) so a price-only trace still replays.

2. **Prices** — the piecewise-constant per-kind spot path, integrated
   exactly: cost over ``[t0, t1)`` is the difference of the cumulative
   price integral, evaluated per slot column. The path holds flat after
   its last update (and past the horizon). Kinds with no price events
   bill at the book transient price.

Trial diversity comes from ``bind``'s bootstrap mode: ``"windows"``
(the ``simulate_many(trace=...)`` default) starts each trial at a
uniformly drawn window boundary of the trace — block-bootstrap over
launch conditions, so N trials sweep the whole timeline; ``"zero"``
(used by the policy evaluator and the lookahead planner) starts every
trial at the context's ``t0`` and replays the one realized timeline,
trials differing only in their independent bootstrap draws — the mode
that keeps price/revocation correlations aligned with policy decisions.

``simulate_many(..., trace=...)`` wraps the trace in a ``ReplayContext``;
``mc.simulate_batch(..., replay=...)`` consumes it. Policies reuse the
same object for spot quotes (``price_at``) and revocation-intensity
observations. A context can be re-based at ``t0 > 0`` (``tail``) so a
lookahead planner replays only the remainder of the trace.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import pricing
from repro.core.transient import (EmpiricalLifetime, LIFETIMES,
                                  MAX_LIFETIME_S)
from repro.traces.schema import Trace

_MIN_WINDOW_OBS = 8          # fewer observations than this -> whole-trace


class _PricePath:
    """Piecewise-constant $/hr path with an exact cumulative integral."""

    def __init__(self, times_s: np.ndarray, prices_hr: np.ndarray,
                 book_hr: float, t0: float):
        if times_s.size == 0:
            times_s = np.array([t0])
            prices_hr = np.array([book_hr])
        # price in force at t0: the last update at or before t0 (or the
        # first update, for traces whose first quote lands after t0)
        i0 = max(int(np.searchsorted(times_s, t0, side="right")) - 1, 0)
        knots = np.concatenate([[t0], times_s[i0 + 1:]])
        vals = np.concatenate([[prices_hr[i0]], prices_hr[i0 + 1:]])
        # cumulative integral of the step function at each knot; the last
        # segment extends flat to +inf via linear extrapolation below
        seg = np.diff(knots) * vals[:-1]
        self._knots = knots
        self._vals = vals
        self._cum = np.concatenate([[0.0], np.cumsum(seg)])
        self._t0 = t0

    def price_at(self, t_s) -> np.ndarray:
        """Spot $/hr at ``t_s`` seconds after the context's t0."""
        t = np.asarray(t_s, dtype=np.float64) + self._t0
        i = np.clip(np.searchsorted(self._knots, t, side="right") - 1,
                    0, self._vals.size - 1)
        return self._vals[i]

    def integral_usd(self, t_start_s, t_end_s) -> np.ndarray:
        """$ billed for one instance active on ``[t_start, t_end)``."""
        a = np.asarray(t_start_s, dtype=np.float64) + self._t0
        b = np.asarray(t_end_s, dtype=np.float64) + self._t0

        def cum(t):
            t = np.clip(t, self._knots[0], None)
            i = np.clip(np.searchsorted(self._knots, t, side="right") - 1,
                        0, self._vals.size - 1)
            return self._cum[i] + (t - self._knots[i]) * self._vals[i]

        return np.maximum(cum(b) - cum(a), 0.0) / 3600.0


class ReplayContext:
    """A ``Trace`` compiled for vectorized playback from time ``t0``."""

    def __init__(self, trace: Trace, *, t0: float = 0.0, n_windows: int = 8,
                 zone: Optional[str] = None, bootstrap: str = "windows"):
        if not 0.0 <= t0 < trace.horizon_s:
            raise ValueError(f"t0={t0} outside trace horizon "
                             f"{trace.horizon_s}")
        if bootstrap not in ("windows", "zero"):
            raise ValueError(f"unknown bootstrap mode {bootstrap!r}")
        self.trace = trace
        self.t0 = float(t0)
        self.n_windows = int(n_windows)
        self.zone = zone
        self.bootstrap = bootstrap
        self.remaining_s = trace.horizon_s - self.t0
        self._prices: Dict[str, _PricePath] = {}
        self._windows: Dict[str, list] = {}
        self._all_obs: Dict[str, object] = {}
        unknown = set(trace.kinds) - set(pricing.SERVER_TYPES)
        if unknown:
            raise ValueError(f"trace has unknown server kinds {sorted(unknown)}; "
                             f"known: {sorted(pricing.SERVER_TYPES)}")
        kinds = set(trace.kinds) | set(LIFETIMES)
        self._has_prices: Dict[str, bool] = {}
        self._revoke_ts: Dict[str, np.ndarray] = {}   # sorted event times
        for kind in kinds:
            ts, ps = trace.price_series(kind, zone)
            book = pricing.SERVER_TYPES[kind].transient_hr
            self._prices[kind] = _PricePath(ts, ps, book, self.t0)
            self._has_prices[kind] = ts.size > 0
            self._compile_lifetimes(kind)

    def _compile_lifetimes(self, kind: str) -> None:
        c = self.trace.columns(event="revoke", kind=kind, zone=self.zone)
        ts, lives = c["t"], c["value"]
        self._revoke_ts[kind] = ts          # sorted (Trace sorts events)
        sel = ts >= self.t0
        ts, lives = ts[sel], lives[sel]
        if lives.size == 0:
            self._all_obs[kind] = LIFETIMES[kind]
            self._windows[kind] = [LIFETIMES[kind]] * self.n_windows
            return
        full = EmpiricalLifetime(lives)
        self._all_obs[kind] = full
        edges = np.linspace(self.t0, self.trace.horizon_s,
                            self.n_windows + 1)
        wins = []
        for w in range(self.n_windows):
            m = (ts >= edges[w]) & (ts < edges[w + 1])
            wins.append(EmpiricalLifetime(lives[m])
                        if int(m.sum()) >= _MIN_WINDOW_OBS else full)
        self._windows[kind] = wins

    def tail(self, dt_s: float) -> "ReplayContext":
        """Context re-based ``dt_s`` seconds later, in ``"zero"`` mode —
        a lookahead planner asks "what if I launch X *now*", so its plan
        trials all replay the realized remainder of the trace."""
        t0 = min(self.t0 + max(dt_s, 0.0), self.trace.horizon_s * 0.999)
        return ReplayContext(self.trace, t0=t0, n_windows=self.n_windows,
                             zone=self.zone, bootstrap="zero")

    def window_at(self, t_abs_s: np.ndarray) -> np.ndarray:
        """Window index containing each (absolute-trace-time) instant."""
        frac = (np.asarray(t_abs_s, dtype=np.float64) - self.t0) \
            / max(self.remaining_s, 1e-9)
        return np.clip((frac * self.n_windows).astype(np.int64), 0,
                       self.n_windows - 1)

    # -- engine-facing API -------------------------------------------------

    def bind(self, n_trials: int, rng: np.random.Generator,
             bootstrap: Optional[str] = None) -> "BoundReplay":
        """Assign each trial its replay start offset (see module doc)."""
        mode = bootstrap or self.bootstrap
        if mode == "windows":
            w = rng.integers(self.n_windows, size=n_trials)
            offsets = w * (self.remaining_s / self.n_windows)
        elif mode == "zero":
            offsets = np.zeros(n_trials)
        else:
            raise ValueError(f"unknown bootstrap mode {mode!r}; "
                             "expected 'windows' or 'zero'")
        return BoundReplay(self, offsets)

    def price_at(self, kind: str, t_s) -> np.ndarray:
        return self._prices[kind].price_at(t_s)

    def cost_usd(self, kind: str, t_start_s, t_end_s) -> np.ndarray:
        return self._prices[kind].integral_usd(t_start_s, t_end_s)

    def has_prices(self, kind: str) -> bool:
        return self._has_prices.get(kind, False)

    def revocation_intensity(self, kind: str, t_s: float,
                             lookback_s: float = 3600.0) -> float:
        """Observed revocations/hour for ``kind`` in the trailing window."""
        ts = self._revoke_ts.get(kind, np.empty(0))
        t_abs = self.t0 + t_s
        lo = max(t_abs - lookback_s, 0.0)
        n = int(np.searchsorted(ts, t_abs, side="left")
                - np.searchsorted(ts, lo, side="left"))
        return n / max((t_abs - lo) / 3600.0, 1e-9)

    def p_revoked_by(self, kind: str, t_s: float) -> float:
        """Empirical CDF over the context's observations (planner hook)."""
        return self._all_obs[kind].p_revoked_by(t_s)


@dataclasses.dataclass(frozen=True)
class BoundReplay:
    """A ``ReplayContext`` plus per-trial replay start offsets."""
    ctx: ReplayContext
    offset_s: np.ndarray          # (N,) float64, added to every sim time

    def lifetimes(self, kind: str, trial_idx: np.ndarray, at_s: np.ndarray,
                  rng: np.random.Generator) -> np.ndarray:
        """One bootstrap lifetime per entry of ``trial_idx``, conditioned
        on the trace window each server *activates* in (``at_s`` is the
        per-entry simulation time of the activation)."""
        idx = np.asarray(trial_idx)
        at = np.broadcast_to(np.asarray(at_s, dtype=np.float64), idx.shape)
        out = np.empty(idx.size, dtype=np.float64)
        wins = self.ctx.window_at(self.ctx.t0 + self.offset_s[idx] + at)
        for w in np.unique(wins):
            m = wins == w
            out[m] = self.ctx._windows[kind][int(w)].sample(rng,
                                                            int(m.sum()))
        return np.minimum(out, MAX_LIFETIME_S)

    def cost_usd(self, kind: str, t_start_s, t_end_s) -> np.ndarray:
        """$ per trial for [t_start, t_end), full-length trial-order
        arrays (offsets applied elementwise)."""
        return self.ctx.cost_usd(kind, self.offset_s + t_start_s,
                                 self.offset_s + t_end_s)

    def has_prices(self, kind: str) -> bool:
        return self.ctx.has_prices(kind)


def context_for(trace) -> ReplayContext:
    """Coerce a ``Trace`` (memoized) or pass through a ``ReplayContext``.

    The compiled context is memoized on the trace object itself (the
    dataclass is frozen but not slotted), so its lifetime is exactly the
    trace's — no global cache to leak when traces are streamed through
    ``simulate_many(trace=...)``/``price_at``. The reference cycle
    (trace -> ctx -> trace) is ordinary gc fodder.
    """
    if isinstance(trace, ReplayContext):
        return trace
    ctx = getattr(trace, "_default_ctx", None)
    if ctx is None:
        ctx = ReplayContext(trace)
        object.__setattr__(trace, "_default_ctx", ctx)
    return ctx
