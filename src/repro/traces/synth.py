"""Deterministic synthetic trace generators.

Two stochastic processes, both seeded and reproducible:

- **Lifetimes**: revocation observations drawn from the paper's Fig-3
  calibrated mixtures (``transient.LIFETIMES``), with optional
  *burst windows* that scale lifetimes down for a stretch of the horizon
  — the time-correlated revocation behaviour measured by the follow-up
  characterization study (arXiv:2004.03072) that static mixtures miss.
- **Spot prices**: a mean-reverting (Ornstein-Uhlenbeck) process in
  log-price around a per-kind mean level, sampled on a fixed grid.
  Regime shifts (demand surges) move the mean level for a window, which
  is what makes "which server type is cheapest per step?" change over
  time — the question the online policies exist to answer.

``trace_from_model`` is the null generator: i.i.d. lifetime draws and
constant book prices. Replaying it must agree statistically with direct
distribution sampling (pinned in ``tests/test_traces.py``), which anchors
the whole replay path to the validated engine.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import pricing
from repro.core.transient import LIFETIMES, LifetimeModel
from repro.traces.schema import Trace, TraceEvent

DAY_S = 24 * 3600.0

# (t0_frac, t1_frac, factor) — multiplicative windows on the horizon
Regime = Tuple[float, float, float]


def ou_log_price_path(rng: np.random.Generator, n: int, dt_s: float,
                      sigma: float, reversion_hr: float = 2.0) -> np.ndarray:
    """Discrete OU in log-space: x_{i+1} = a x_i + sigma*sqrt(1-a^2) eps.

    ``sigma`` is the stationary std of log-price; ``reversion_hr`` the
    mean-reversion time constant. Returns exp(x), mean ~1, length n.
    """
    a = math.exp(-(dt_s / 3600.0) / reversion_hr)
    noise = rng.normal(size=n) * sigma * math.sqrt(max(1.0 - a * a, 0.0))
    x = np.empty(n)
    x[0] = rng.normal() * sigma
    for i in range(1, n):
        x[i] = a * x[i - 1] + noise[i]
    return np.exp(x)


def _regime_factor(t: np.ndarray, horizon_s: float,
                   regimes: Sequence[Regime]) -> np.ndarray:
    f = np.ones_like(t)
    for t0, t1, factor in regimes:
        f = np.where((t >= t0 * horizon_s) & (t < t1 * horizon_s),
                     f * factor, f)
    return f


def synthetic_trace(name: str, *, seed: int, horizon_s: float = DAY_S,
                    kinds: Sequence[str] = ("K80", "P100", "V100"),
                    zones: Sequence[str] = ("us-east1",),
                    price_interval_s: float = 900.0,
                    price_sigma: float = 0.05,
                    price_regimes: Optional[Dict[str, Sequence[Regime]]] = None,
                    revocations_per_kind: int = 384,
                    lifetime_burst: Optional[Dict[str, Sequence[Regime]]] = None,
                    capacity_events_per_kind: int = 4,
                    models: Optional[Dict[str, LifetimeModel]] = None
                    ) -> Trace:
    """One deterministic synthetic market timeline.

    ``price_regimes[kind]`` multiplies the OU mean level inside fractional
    windows of the horizon; ``lifetime_burst[kind]`` scales lifetimes of
    revocations observed inside the window (shorter = a revocation burst).
    """
    rng = np.random.default_rng(seed)
    models = models or LIFETIMES
    events: List[TraceEvent] = []
    grid = np.arange(0.0, horizon_s, price_interval_s)
    for kind in kinds:
        book = pricing.SERVER_TYPES[kind].transient_hr
        for zone in zones:
            # prices: OU around book, regime-shifted
            path = book * ou_log_price_path(rng, len(grid), price_interval_s,
                                            price_sigma)
            path = path * _regime_factor(grid, horizon_s,
                                         (price_regimes or {}).get(kind, ()))
            events.extend(TraceEvent(float(t), "price", kind, zone,
                                     float(p))
                          for t, p in zip(grid, path))
            # revocation observations: uniform event times, mixture
            # lifetimes, burst windows shorten them
            n_rev = revocations_per_kind
            ts = np.sort(rng.uniform(0.0, horizon_s, size=n_rev))
            lives = models[kind].sample(rng, n_rev)
            burst = _regime_factor(ts, horizon_s,
                                   (lifetime_burst or {}).get(kind, ()))
            lives = np.maximum(lives * burst, 1.0)
            events.extend(TraceEvent(float(t), "revoke", kind, zone,
                                     float(v))
                          for t, v in zip(ts, lives))
            # coarse capacity signal (policies only; engine ignores it)
            for t in rng.uniform(0.0, horizon_s,
                                 size=capacity_events_per_kind):
                events.append(TraceEvent(float(t), "capacity", kind, zone,
                                         float(rng.integers(4, 64))))
    return Trace(name=name, horizon_s=horizon_s, events=tuple(events),
                 source="synthetic", seed=seed)


def trace_from_model(*, seed: int, horizon_s: float = DAY_S,
                     kinds: Sequence[str] = ("K80", "P100", "V100", "PS"),
                     zone: str = "us-east1",
                     events_per_kind: int = 4096,
                     models: Optional[Dict[str, LifetimeModel]] = None
                     ) -> Trace:
    """Null-hypothesis trace: i.i.d. mixture lifetimes + constant book
    prices. Replaying it must reproduce distribution-sampling statistics."""
    rng = np.random.default_rng(seed)
    models = models or LIFETIMES
    events: List[TraceEvent] = []
    for kind in kinds:
        events.append(TraceEvent(0.0, "price", kind, zone,
                                 pricing.SERVER_TYPES[kind].transient_hr))
        ts = rng.uniform(0.0, horizon_s, size=events_per_kind)
        lives = models[kind].sample(rng, events_per_kind)
        events.extend(TraceEvent(float(t), "revoke", kind, zone, float(v))
                      for t, v in zip(ts, lives))
    return Trace(name=f"model-iid-{seed}", horizon_s=horizon_s,
                 events=tuple(events), source="synthetic", seed=seed)


def default_trace_suite(seed: int = 0) -> List[Trace]:
    """The deterministic three-trace suite the policy benchmark replays.

    Training runs last ~1-2 h, so every regime window sits inside the
    first few hours of the 24 h horizon — the dynamics must bite *during*
    a run for online adaptation to matter.

    calm      low price noise, no regimes — online policies must not
              re-provision mid-run (hysteresis holds against OU noise;
              any win over a static baseline is the initial pick only).
    volatile  a demand surge holds P100/V100 at ~2x for the first ~26 min,
              then releases — the cheapest $/step type crosses over
              mid-run, so a static pick is wrong in one half or the other
              and online policies switch at the next decision epoch.
    bursty    a V100 fire sale (price x0.75) coinciding with a revocation
              storm (lifetimes x0.05) for the first ~3 h. The quote alone
              cannot say whether the sale is worth taking — that depends
              on the lifetime process, which only a planner that
              *simulates* the trace (LookaheadMC) evaluates; greedy's
              quote-only score happens to land safely here (the PS cap
              discounts the 4xV100 fleet rate) but has no way to price
              the storm itself.
    """
    surge = {"P100": [(0.0, 0.018, 2.2)], "V100": [(0.0, 0.018, 2.1)]}
    trap_price = {"V100": [(0.0, 0.125, 0.75)]}
    trap_life = {"V100": [(0.0, 0.125, 0.05)]}
    return [
        synthetic_trace("calm", seed=seed, price_sigma=0.02),
        synthetic_trace("volatile", seed=seed + 1, price_sigma=0.08,
                        price_regimes=surge),
        synthetic_trace("bursty", seed=seed + 2, price_sigma=0.05,
                        price_regimes=trap_price,
                        lifetime_burst=trap_life),
    ]
