"""Trace schema: a timestamped record of transient-market conditions.

A ``Trace`` is an ordered sequence of ``TraceEvent``s over a horizon,
each tagged with a server type (``kind``) and a zone:

``price``     the spot ($/hr) for ``kind`` in ``zone`` changed to ``value``
              (piecewise-constant until the next update for that pair).
``revoke``    an instance of ``kind`` in ``zone`` was revoked after
              ``value`` seconds of life — an *observation* of the lifetime
              process, what replay bootstrap-resamples from.
``capacity``  the number of ``kind`` slots the provider would currently
              fulfil in ``zone`` changed to ``value`` (policies read this
              as an availability signal; the engine does not consume it).

Serialization is deliberately dual:

- **JSONL** (interchange, human-diffable): one header line
  ``{"trace": {...meta...}}`` followed by one event per line. Python's
  ``json`` round-trips finite IEEE-754 doubles exactly (``repr``-based),
  so the format is lossless.
- **npz** (bulk, mmap-friendly): columnar float64/int64 arrays plus
  small vocab arrays for the categorical columns and the meta as a JSON
  string — what the vectorized replay path loads.

Both directions are pinned lossless in ``tests/test_traces.py``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

EVENT_KINDS = ("price", "revoke", "capacity")
_FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True, order=True)
class TraceEvent:
    """One timestamped observation. Ordered by (t, event, kind, zone)."""
    t: float                  # seconds since trace start, in [0, horizon_s]
    event: str                # "price" | "revoke" | "capacity"
    kind: str                 # server type: "K80" | "P100" | "V100" | "PS"
    zone: str                 # e.g. "us-east1"
    value: float              # price: $/hr; revoke: lifetime_s; capacity: slots

    def __post_init__(self):
        if self.event not in EVENT_KINDS:
            raise ValueError(f"unknown event {self.event!r}; "
                             f"expected one of {EVENT_KINDS}")
        if not (self.t >= 0.0):
            raise ValueError(f"event time must be >= 0, got {self.t}")
        if self.event in ("price", "revoke") and not (self.value > 0.0):
            raise ValueError(f"{self.event} value must be > 0, "
                             f"got {self.value}")


@dataclasses.dataclass(frozen=True)
class Trace:
    """An immutable, time-sorted event timeline with metadata.

    ``events`` are sorted on construction (stable), so two traces built
    from the same events in any order compare equal.
    """
    name: str
    horizon_s: float
    events: Tuple[TraceEvent, ...]
    source: str = "synthetic"           # "synthetic" | "recorded"
    seed: Optional[int] = None          # generator seed, if synthetic

    def __post_init__(self):
        if self.horizon_s <= 0:
            raise ValueError(f"horizon_s must be > 0, got {self.horizon_s}")
        evs = tuple(sorted(self.events))
        for e in evs:
            if e.t > self.horizon_s:
                raise ValueError(f"event at t={e.t} beyond horizon "
                                 f"{self.horizon_s}")
        object.__setattr__(self, "events", evs)

    # -- columnar access (what replay vectorizes over) ---------------------

    def columns(self, event: Optional[str] = None,
                kind: Optional[str] = None,
                zone: Optional[str] = None) -> Dict[str, np.ndarray]:
        """Filtered columns as arrays: ``{"t": f8[n], "value": f8[n]}``."""
        sel = [e for e in self.events
               if (event is None or e.event == event)
               and (kind is None or e.kind == kind)
               and (zone is None or e.zone == zone)]
        return {"t": np.array([e.t for e in sel], dtype=np.float64),
                "value": np.array([e.value for e in sel], dtype=np.float64)}

    def lifetimes(self, kind: str) -> np.ndarray:
        """All observed lifetimes (seconds) for ``kind``, in event order."""
        return self.columns(event="revoke", kind=kind)["value"]

    def price_series(self, kind: str,
                     zone: Optional[str] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """(times, $/hr) of the piecewise-constant price path for ``kind``.

        With multiple zones and ``zone=None``, updates from every zone are
        merged in time order (the replay path treats the trace as one
        market; per-zone playback passes an explicit zone).
        """
        c = self.columns(event="price", kind=kind, zone=zone)
        return c["t"], c["value"]

    @property
    def kinds(self) -> Tuple[str, ...]:
        return tuple(sorted({e.kind for e in self.events}))

    @property
    def zones(self) -> Tuple[str, ...]:
        return tuple(sorted({e.zone for e in self.events}))

    def window(self, t0: float, t1: float) -> "Trace":
        """Sub-trace of events with ``t0 <= t < t1``, times re-zeroed."""
        evs = tuple(dataclasses.replace(e, t=e.t - t0) for e in self.events
                    if t0 <= e.t < t1)
        return Trace(name=f"{self.name}[{t0:g}:{t1:g}]",
                     horizon_s=max(t1 - t0, 1e-9), events=evs,
                     source=self.source, seed=self.seed)

    # -- JSONL -------------------------------------------------------------

    def _meta(self) -> Dict:
        return {"name": self.name, "horizon_s": self.horizon_s,
                "source": self.source, "seed": self.seed,
                "version": _FORMAT_VERSION}

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(json.dumps({"trace": self._meta()}) + "\n")
            for e in self.events:
                f.write(json.dumps({"t": e.t, "event": e.event,
                                    "kind": e.kind, "zone": e.zone,
                                    "value": e.value}) + "\n")

    @staticmethod
    def from_jsonl(path: str) -> "Trace":
        with open(path) as f:
            header = json.loads(f.readline())
            if "trace" not in header:
                raise ValueError(f"{path}: first line must be the "
                                 "{'trace': ...} header")
            meta = header["trace"]
            if meta.get("version", 1) > _FORMAT_VERSION:
                raise ValueError(f"{path}: trace format version "
                                 f"{meta['version']} is newer than "
                                 f"{_FORMAT_VERSION}")
            events = []
            for line in f:
                if not line.strip():
                    continue
                d = json.loads(line)
                events.append(TraceEvent(t=d["t"], event=d["event"],
                                         kind=d["kind"], zone=d["zone"],
                                         value=d["value"]))
        return Trace(name=meta["name"], horizon_s=meta["horizon_s"],
                     events=tuple(events),
                     source=meta.get("source", "recorded"),
                     seed=meta.get("seed"))

    # -- npz ---------------------------------------------------------------

    def to_npz(self, path: str) -> None:
        kinds = self.kinds or ("",)
        zones = self.zones or ("",)
        kidx = {k: i for i, k in enumerate(kinds)}
        zidx = {z: i for i, z in enumerate(zones)}
        eidx = {e: i for i, e in enumerate(EVENT_KINDS)}
        np.savez(
            path,
            t=np.array([e.t for e in self.events], dtype=np.float64),
            value=np.array([e.value for e in self.events], dtype=np.float64),
            event=np.array([eidx[e.event] for e in self.events],
                           dtype=np.int64),
            kind=np.array([kidx[e.kind] for e in self.events],
                          dtype=np.int64),
            zone=np.array([zidx[e.zone] for e in self.events],
                          dtype=np.int64),
            kind_vocab=np.array(kinds), zone_vocab=np.array(zones),
            meta=np.array(json.dumps(self._meta())))

    @staticmethod
    def from_npz(path: str) -> "Trace":
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            kinds = [str(k) for k in z["kind_vocab"]]
            zones = [str(s) for s in z["zone_vocab"]]
            events = tuple(
                TraceEvent(t=float(t), event=EVENT_KINDS[int(ev)],
                           kind=kinds[int(k)], zone=zones[int(s)],
                           value=float(v))
                for t, ev, k, s, v in zip(z["t"], z["event"], z["kind"],
                                          z["zone"], z["value"]))
        return Trace(name=meta["name"], horizon_s=meta["horizon_s"],
                     events=events, source=meta.get("source", "recorded"),
                     seed=meta.get("seed"))
